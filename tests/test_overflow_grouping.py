"""Adversarial overflow-heavy grouping: key sets where most rows COLLIDE
(many distinct keys hashing into few buckets — including keys crafted to
land in ONE bucket), so nearly every row rides the overflow path instead of
the bucket table. Verifies the segment-reduce aggregation (kernels/ref.py)
and the device-side partial merge (offload.merge_groups_device) stay exact
vs `group_aggregate_exact`, solo and at 1/2/4 cluster nodes.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               merge_group_partials, open_connection,
                               table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column
from repro.kernels import ops as kops
from repro.kernels import ref as kref

COLS = (Column("c0", "i32"), Column("c1"), Column("c2"))


def same_bucket_keys(n_distinct: int, n_buckets: int,
                     bucket: int = 0) -> np.ndarray:
    """Distinct keys that all Fibonacci-hash into one bucket — every row
    but the claimant overflows."""
    cand = np.arange(1, 200000, dtype=np.int32)
    b = np.asarray(kref.bucket_of(jnp.asarray(cand), n_buckets))
    picked = cand[b == bucket][:n_distinct]
    assert len(picked) == n_distinct, "search range too small"
    return picked


def assert_exact(merged: dict, keys: np.ndarray, vals: np.ndarray) -> None:
    exact = kref.group_aggregate_exact(keys, vals)
    assert set(merged) == set(exact)
    for k in exact:
        c, s, mn, mx = merged[k]
        ce, se, mne, mxe = exact[k]
        assert c == ce, k
        np.testing.assert_array_equal(np.asarray(s, np.float64), se)
        np.testing.assert_array_equal(np.asarray(mn, np.float64), mne)
        np.testing.assert_array_equal(np.asarray(mx, np.float64), mxe)


def adversarial_sets(rng):
    nb = 32
    # (a) every key in ONE bucket: 1 claimed row, n-1 overflow rows
    one = same_bucket_keys(60, nb)
    keys_a = one[rng.integers(0, len(one), 480)]
    # (b) 500 distinct keys >> 32 buckets: most rows overflow somewhere
    keys_b = rng.integers(0, 500, 480).astype(np.int32)
    # (c) heavy skew: one dominant key + a long colliding tail
    keys_c = np.concatenate([np.full(400, int(one[0]), np.int32),
                             one[:40], one[:40]])
    return nb, [keys_a, keys_b, keys_c]


def test_kernel_overflow_heavy_exact(rng):
    """Kernel + overflow merge (solo, kops.group_aggregate_full) is exact
    when nearly all rows collide."""
    nb, key_sets = adversarial_sets(rng)
    for i, keys in enumerate(key_sets):
        vals = rng.integers(-9, 9, (len(keys), 2)).astype(np.float32)
        got = kops.group_aggregate_full(jnp.asarray(keys),
                                        jnp.asarray(vals), n_buckets=nb)
        exact = kref.group_aggregate_exact(keys, vals)
        assert set(got) == set(exact)
        for k in exact:
            assert got[k][0] == exact[k][0]
            np.testing.assert_array_equal(np.asarray(got[k][1], np.float64),
                                          exact[k][1])
        raw = kops.group_aggregate(jnp.asarray(keys), jnp.asarray(vals),
                                   n_buckets=nb)
        if i < 2:   # sets (a)/(b) really are overflow-heavy; (c)'s dominant
            #         key claims its bucket, so only the tail overflows
            assert np.asarray(raw["overflow_mask"]).mean() > 0.5


def test_solo_pipeline_overflow_heavy_exact(rng):
    nb, key_sets = adversarial_sets(rng)
    for keys in key_sets:
        n = len(keys)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        ft = FTable("t", COLS, n_rows=n)
        alloc_table_mem(qp, ft)
        d = {"c0": keys,
             "c1": rng.integers(-9, 9, n).astype(np.float32),
             "c2": rng.integers(-9, 9, n).astype(np.float32)}
        table_write(qp, ft, ft.encode(d))
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=nb),)
        res = farview_request(qp, ft, pipe).finalize()
        merged = merge_group_partials(ft, pipe, [res]).groups
        assert_exact(merged, keys, np.stack([d["c1"], d["c2"]], 1))


@pytest.mark.parametrize("k", (1, 2, 4))
@pytest.mark.parametrize("partitioner", ("range", "hash"))
def test_cluster_overflow_heavy_exact(rng, k, partitioner):
    """1/2/4 nodes x range/hash partitions: node partials full of overflow
    rows still merge exactly through the device-side segment-reduce."""
    nb, key_sets = adversarial_sets(rng)
    for keys in key_sets:
        n = len(keys)
        cl = FarCluster(k)
        cqp = cl.open_connection()
        ft = FTable("t", COLS, n_rows=n)
        ct = cl.alloc_table_mem(
            cqp, ft, partitioner=partitioner,
            keys=keys if partitioner != "range" else None)
        d = {"c0": keys,
             "c1": rng.integers(-9, 9, n).astype(np.float32),
             "c2": rng.integers(-9, 9, n).astype(np.float32)}
        cl.table_write(cqp, ct, ft.encode(d))
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=nb),)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        assert_exact(res.groups, keys, np.stack([d["c1"], d["c2"]], 1))


def test_cluster_distinct_overflow_heavy(rng):
    nb, key_sets = adversarial_sets(rng)
    keys = key_sets[0]
    n = len(keys)
    for k in (1, 2, 4):
        cl = FarCluster(k)
        cqp = cl.open_connection()
        ft = FTable("t", COLS, n_rows=n)
        ct = cl.alloc_table_mem(cqp, ft, partitioner="hash", keys=keys)
        d = {"c0": keys,
             "c1": np.zeros(n, np.float32), "c2": np.zeros(n, np.float32)}
        cl.table_write(cqp, ct, ft.encode(d))
        res = cl.farview_request(
            cqp, ct, (op.Distinct(("c0",), n_buckets=nb),)).finalize()
        assert set(res.groups) == set(np.unique(keys).tolist())
