"""farlint (tools/analyze, repro.analyze) — the analyzer analyzed.

Per rule: a positive fixture (the seeded violation is caught, with the
right rule id on the right line) and a negative fixture (guarded /
suppressed / finalize-boundary code passes). Plus the baseline
add/expire lifecycle, and — the teeth — a run over the real `src/`
tree asserting zero non-baselined findings, which makes this test file
the tier-1 enforcement point for the repo's concurrency and laziness
invariants (docs/analysis.md).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analyze import (
    RULES,
    analyze_paths,
    analyze_source,
    apply_baseline,
    load_baseline,
    rule_id,
    save_baseline,
)

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "tools" / "analyze" / "baseline.json"


def run(src: str, path: str = "fixture.py"):
    return analyze_source(textwrap.dedent(src), path)


def lines_of(findings, rule):
    return [f.line for f in findings if f.rule == rule]


# ------------------------------------------------------------------ plumbing
def test_rule_registry_and_aliases():
    assert rule_id("FL001") == "FL001"
    assert rule_id("lock-discipline") == "FL001"
    assert rule_id("host-sync") == "FL002"
    assert rule_id("no-such-rule") is None
    assert rule_id("async-blocking") == "FL006"
    assert rule_id("await-bound") == "FL007"
    assert set(RULES) == {"FL000", "FL001", "FL002", "FL003", "FL004",
                          "FL005", "FL006", "FL007"}


def test_syntax_error_is_reported_not_raised():
    fs = run("def broken(:\n    pass\n")
    assert [f.rule for f in fs] == ["FL000"]
    assert "does not parse" in fs[0].message


# ------------------------------------------------------- FL001 lock discipline
_LOCKED_CLASS = """
    import threading

    class Monitor:
        def __init__(self):
            self._lock = threading.Lock()
            self.entries = []        # guarded-by: self._lock

        def bad_read(self):
            return len(self.entries)            # line 10

        def good_read(self):
            with self._lock:
                return len(self.entries)

        def bad_write(self, x):
            self.entries.append(x)              # line 17
"""


def test_lock_discipline_flags_unguarded_method_access():
    fs = run(_LOCKED_CLASS)
    assert lines_of(fs, "FL001") == [10, 17]
    assert all("self._lock" in f.message for f in fs)


def test_lock_discipline_init_and_guarded_access_pass():
    fs = run("""
    import threading

    class Ok:
        def __init__(self):
            self._lock = threading.Lock()
            self.state = {}          # guarded-by: self._lock
            self.state["seed"] = 1   # still __init__: exempt

        def read(self):
            with self._lock:
                return dict(self.state)
    """)
    assert lines_of(fs, "FL001") == []


def test_lock_discipline_rebinds_receiver():
    fs = run("""
    import threading

    class Heat:
        def __init__(self):
            self._lock = threading.Lock()
            self.rows = [0, 0]       # guarded-by: self._lock

    def drain(heat):
        heat.rows[0] += 1                       # line 10: needs heat._lock
        with heat._lock:
            heat.rows[1] += 1
    """)
    assert lines_of(fs, "FL001") == [10]
    assert "heat._lock" in fs[0].message


def test_lock_discipline_module_global():
    fs = run("""
    import threading

    _CACHE = {}                      # guarded-by: _CACHE_LOCK
    _CACHE_LOCK = threading.Lock()

    def get(key):
        if key in _CACHE:                       # line 8
            return _CACHE[key]                  # line 9

    def get_locked(key):
        with _CACHE_LOCK:
            return _CACHE.get(key)
    """)
    assert lines_of(fs, "FL001") == [8, 9]


def test_lock_discipline_other_class_same_attr_name_not_flagged():
    fs = run("""
    import threading

    class Guarded:
        def __init__(self):
            self._lock = threading.Lock()
            self.nodes = []          # guarded-by: self._lock

    class Unrelated:
        def __init__(self):
            self.nodes = [1, 2]

        def read(self):
            return self.nodes[0]     # Unrelated.nodes is not guarded
    """)
    assert lines_of(fs, "FL001") == []


def test_suppression_clears_finding_and_requires_justification():
    ok = run("""
    import threading

    class M:
        def __init__(self):
            self._lock = threading.Lock()
            self.xs = []             # guarded-by: self._lock

        def racy_len(self):
            # farlint: ok lock-discipline -- len() is atomic enough here
            return len(self.xs)
    """)
    assert lines_of(ok, "FL001") == []
    assert lines_of(ok, "FL000") == []

    bad = run("""
    import threading

    class M:
        def __init__(self):
            self._lock = threading.Lock()
            self.xs = []             # guarded-by: self._lock

        def racy_len(self):
            return len(self.xs)      # farlint: ok lock-discipline
    """)
    # no justification: suppression is invalid AND the finding stands
    assert lines_of(bad, "FL000") == [10]
    assert lines_of(bad, "FL001") == [10]


# ------------------------------------------------------------ FL002 host-sync
_SYNC_SRC = """
    import jax.numpy as jnp
    import numpy as np

    def dispatch(keys):
        res = jnp.cumsum(keys)
        host = np.asarray(res)                  # line 7: flagged
        n = int(res[0])                         # line 8: flagged
        res.block_until_ready()                 # line 9: flagged
        return host, n

    def finalize_dispatch(keys):
        res = jnp.cumsum(keys)
        return np.asarray(res)                  # boundary by name: ok

    def shapes_only(pages):
        n = int(pages.shape[0])                 # sanitized: ok
        return np.asarray([n])                  # host literal: ok
"""


def test_host_sync_flags_in_scope_and_respects_boundaries():
    fs = run(_SYNC_SRC, path="kernels/fixture.py")
    assert lines_of(fs, "FL002") == [7, 8, 9]


def test_host_sync_only_applies_on_dispatch_path_modules():
    assert run(_SYNC_SRC, path="distributed/fixture.py") == []


def test_host_sync_boundary_marker_comment():
    fs = run("""
    import jax.numpy as jnp
    import numpy as np

    # farlint: finalize-boundary
    def merge(parts):
        return np.asarray(jnp.concatenate(parts))
    """, path="core/offload.py")
    assert lines_of(fs, "FL002") == []


def test_host_sync_exempts_helpers_of_boundaries():
    fs = run("""
    import jax.numpy as jnp
    import numpy as np

    def _pull(res):
        return np.asarray(res)       # called only from a finalize fn: ok

    def finalize_all(res):
        return _pull(jnp.cumsum(res))
    """, path="kernels/fixture.py")
    assert lines_of(fs, "FL002") == []


def test_host_sync_params_are_untainted():
    fs = run("""
    import numpy as np

    def pack(rows, n_valid):
        out = np.asarray(rows)       # host-side param: not a device value
        return out[: int(n_valid)]
    """, path="kernels/fixture.py")
    assert lines_of(fs, "FL002") == []


# ------------------------------------------------------- FL003/4/5 retrace
def test_static_argnames_must_name_a_parameter():
    fs = run("""
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("n_rows", "typo_arg"))
    def kernel(pages, n_rows):
        return pages[:n_rows]
    """)
    assert lines_of(fs, "FL003") == [5]
    assert "typo_arg" in fs[0].message


def test_static_arg_call_site_must_be_hashable():
    fs = run("""
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("widths",))
    def kernel(pages, widths):
        return pages

    def caller(pages):
        good = kernel(pages, widths=(8, 16))
        bad = kernel(pages, widths=[8, 16])     # line 11: list is unhashable
        return good, bad
    """)
    assert lines_of(fs, "FL003") == [11]


def test_jit_over_bound_method_flagged_and_suppressible():
    fs = run("""
    import jax

    class Pipe:
        def __init__(self):
            self._jit = jax.jit(self._entry)    # line 6

        def _entry(self, x):
            return x
    """)
    assert lines_of(fs, "FL004") == [6]

    ok = run("""
    import jax

    class Pipe:
        def __init__(self):
            # farlint: ok jit-closure -- captured attrs are write-once
            self._jit = jax.jit(self._entry)

        def _entry(self, x):
            return x
    """)
    assert lines_of(ok, "FL004") == []


def test_jit_closure_over_mutated_state_flagged():
    fs = run("""
    import jax

    def make(scale):
        table = {"scale": scale}

        @jax.jit
        def step(x):                            # line 7
            return x * table["scale"]

        table["scale"] = scale + 1              # mutated AFTER the def
        return step
    """)
    assert lines_of(fs, "FL004") == [7]
    assert "table" in fs[0].message


def test_jit_closure_initialized_before_def_passes():
    fs = run("""
    import jax

    def make(scale):
        cfg = dict(scale=scale)      # bound once, before the jitted def

        @jax.jit
        def step(x):
            return x * cfg["scale"]

        return step
    """)
    assert lines_of(fs, "FL004") == []


def test_cache_key_must_cover_every_parameter():
    fs = run("""
    _CACHE = {}

    def compile_thing(schema, signature, interpret):
        key = (schema, signature)               # line 5: omits interpret
        if key not in _CACHE:
            _CACHE[key] = object()
        return _CACHE[key]
    """)
    assert lines_of(fs, "FL005") == [5]
    assert "interpret" in fs[0].message


def test_cache_key_with_all_params_and_non_cache_dicts_pass():
    fs = run("""
    _CACHE = {}

    def compile_thing(schema, signature, interpret):
        norm = bool(interpret)
        key = (schema, signature, norm)         # norm carries interpret
        if key not in _CACHE:
            _CACHE[key] = object()
        return _CACHE[key]

    def group_rows(rows, tag):
        buckets = {}
        key = (tag,)                 # grouping dict, not a compile cache
        buckets[key] = rows
        return buckets
    """)
    assert lines_of(fs, "FL005") == []


# ------------------------------------------------------------------- baseline
def test_baseline_grandfathers_then_expires(tmp_path):
    src = textwrap.dedent(_LOCKED_CLASS)
    findings = analyze_source(src, "mod.py")
    assert len(findings) == 2

    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), findings)
    entries = load_baseline(str(bl))
    assert len(entries) == 2

    # same code: everything grandfathered, nothing new, nothing stale
    res = apply_baseline(analyze_source(src, "mod.py"), entries)
    assert res.new == [] and len(res.grandfathered) == 2
    assert res.stale == []

    # fix ONE violation: its entry goes stale; the other still matches
    fixed = src.replace("return len(self.entries)            # line 10",
                        "with self._lock:\n"
                        "            return len(self.entries)")
    res = apply_baseline(analyze_source(fixed, "mod.py"), entries)
    assert res.new == [] and len(res.grandfathered) == 1
    assert len(res.stale) == 1

    # a NEW violation elsewhere is not absorbed by the baseline
    worse = src + "\n    def sneak(self):\n        return self.entries\n"
    res = apply_baseline(analyze_source(worse, "mod.py"), entries)
    assert len(res.new) == 1 and len(res.grandfathered) == 2


def test_baseline_fingerprints_survive_line_drift():
    src = textwrap.dedent(_LOCKED_CLASS)
    before = analyze_source(src, "mod.py")
    drifted = analyze_source("# a new leading comment\n\n" + src, "mod.py")
    assert ([f.fingerprint for f in before]
            == [f.fingerprint for f in drifted])
    assert [f.line for f in before] != [f.line for f in drifted]


# ----------------------------------------------------------- the real repo
def test_repo_src_is_clean_of_non_baselined_findings():
    entries = load_baseline(str(BASELINE))
    findings = analyze_paths(["src", "benchmarks", "tests"], root=str(REPO))
    res = apply_baseline(findings, entries)
    assert res.new == [], "\n".join(f.render() for f in res.new)
    assert res.stale == [], f"stale baseline entries: {res.stale}"


def test_cli_module_exits_zero_on_repo():
    env = dict(os.environ)
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analyze",
         "--baseline", str(BASELINE)],
        cwd=str(REPO), env=env, capture_output=True, text=True,
        timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new finding(s)" in proc.stdout


def test_cli_fails_on_a_seeded_violation(tmp_path):
    bad = tmp_path / "kernels"
    bad.mkdir()
    (bad / "fix.py").write_text(textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def dispatch(x):
            return np.asarray(jnp.cumsum(x))
    """))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analyze", str(bad)],
        cwd=str(REPO), capture_output=True, text=True, timeout=300)
    assert proc.returncode == 1
    assert "FL002" in proc.stdout


def test_cli_update_baseline_roundtrip(tmp_path):
    bad = tmp_path / "kernels"
    bad.mkdir()
    (bad / "fix.py").write_text(textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def dispatch(x):
            return np.asarray(jnp.cumsum(x))
    """))
    bl = tmp_path / "bl.json"
    first = subprocess.run(
        [sys.executable, "-m", "tools.analyze", str(bad),
         "--baseline", str(bl), "--update-baseline"],
        cwd=str(REPO), capture_output=True, text=True, timeout=300)
    assert first.returncode == 0, first.stdout + first.stderr
    assert json.loads(bl.read_text())["findings"]
    second = subprocess.run(
        [sys.executable, "-m", "tools.analyze", str(bad),
         "--baseline", str(bl)],
        cwd=str(REPO), capture_output=True, text=True, timeout=300)
    assert second.returncode == 0, second.stdout + second.stderr
    assert "1 baselined" in second.stdout


def test_seed_annotations_exist_in_src():
    """The conventions the issue seeds must stay present: losing the
    annotations silently disables the whole lock-discipline pass."""
    health = (REPO / "src/repro/distributed/health.py").read_text()
    cluster = (REPO / "src/repro/core/cluster.py").read_text()
    pipeline = (REPO / "src/repro/core/pipeline.py").read_text()
    rebalance = (REPO / "src/repro/distributed/rebalance.py").read_text()
    assert health.count("guarded-by: self._lock") >= 4
    assert "guarded-by: self._lock" in cluster
    assert "guarded-by: _CACHE_LOCK" in pipeline
    assert rebalance.count("guarded-by: self._lock") >= 2


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))


# ------------------------------------------------------- FL006 async-blocking
NET_PATH = "src/repro/net/fixture.py"


def test_async_blocking_calls_flagged_in_net_scope():
    fs = run("""
    import time
    import socket

    async def handler(sock, fut):
        time.sleep(0.1)                         # line 6
        data = sock.recv(16)                    # line 7
        out = fut.result()                      # line 8
        out.block_until_ready()                 # line 9
        conn = socket.create_connection(("h", 1))   # line 10
        return data, conn
    """, NET_PATH)
    assert lines_of(fs, "FL006") == [6, 7, 8, 9, 10]


def test_async_blocking_ignores_sync_defs_and_async_forms():
    fs = run("""
    import asyncio
    import time

    def worker(sock):
        time.sleep(0.1)             # sync function: worker-thread land
        return sock.recv(16)

    async def handler(loop, reader, pool):
        await asyncio.sleep(0.002)              # the async form
        hdr = await reader.readexactly(16)
        out = await loop.run_in_executor(pool, lambda: time.sleep(1))
        return hdr, out
    """, NET_PATH)
    assert lines_of(fs, "FL006") == []


def test_async_blocking_skips_nested_defs_and_out_of_scope_files():
    nested = """
    import time

    async def handler(loop, pool):
        def thunk():
            time.sleep(0.5)         # executor thunk: allowed
        return await loop.run_in_executor(pool, thunk)
    """
    assert lines_of(run(nested, NET_PATH), "FL006") == []
    # the same blocking calls OUTSIDE src/repro/net/ are not this rule's
    # business (asyncio elsewhere has its own review)
    blocking = """
    import time

    async def handler():
        time.sleep(0.1)
    """
    assert lines_of(run(blocking, "src/repro/core/other.py"), "FL006") == []


def test_async_blocking_respects_finalize_boundary_and_suppression():
    fs = run("""
    import time

    async def finalize_round(fut):
        return fut.result()         # finalize boundary by name

    # farlint: finalize-boundary
    async def drain(fut):
        return fut.result()

    async def shim(fut):
        return fut.result()  # farlint: ok FL006 -- test shim, reviewed
    """, NET_PATH)
    assert lines_of(fs, "FL006") == []


def test_async_blocking_flags_from_time_import_sleep_alias():
    fs = run("""
    from time import sleep as snooze

    async def handler():
        snooze(1)                               # line 5
    """, NET_PATH)
    assert lines_of(fs, "FL006") == [5]


# --------------------------------------------------------- FL007 await-bound
def test_await_bound_flags_unbounded_net_awaits():
    fs = run("""
    import asyncio

    async def serve(reader, writer):
        hdr = await reader.readexactly(16)      # line 5
        body = await reader.read(1024)          # line 6
        line = await reader.readline()          # line 7
        await writer.drain()                    # line 8
        r, w = await asyncio.open_connection("h", 1)    # line 9
        return hdr, body, line, r, w
    """, NET_PATH)
    assert lines_of(fs, "FL007") == [5, 6, 7, 8, 9]


def test_await_bound_accepts_wait_for_wrapped_calls():
    fs = run("""
    import asyncio

    async def serve(reader, writer, io_timeout_s):
        hdr = await asyncio.wait_for(
            reader.readexactly(16), io_timeout_s)
        await asyncio.wait_for(writer.drain(), io_timeout_s)
        r, w = await asyncio.wait_for(
            asyncio.open_connection("h", 1), 30.0)
        return hdr, r, w
    """, NET_PATH)
    assert lines_of(fs, "FL007") == []


def test_await_bound_out_of_scope_and_suppression():
    unbounded = """
    async def serve(reader):
        return await reader.readexactly(16)
    """
    # outside src/repro/net/ the rule does not apply
    assert lines_of(run(unbounded, "src/repro/core/other.py"),
                    "FL007") == []
    fs = run("""
    async def pump(reader):
        return await reader.read(4096)  # farlint: ok FL007 -- lifetime bounded by peers
    """, NET_PATH)
    assert lines_of(fs, "FL007") == []


def test_await_bound_ignores_unrelated_awaits():
    fs = run("""
    import asyncio

    async def serve(queue, task):
        item = await queue.get()        # not a stream read
        await asyncio.sleep(0.1)
        return item, await task
    """, NET_PATH)
    assert lines_of(fs, "FL007") == []
