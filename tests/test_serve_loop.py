"""Continuous-batching serve loop: multi-request slot management."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import smoke_config
from repro.models.lm import LM
from repro.runtime.serve_loop import ContinuousBatcher, Request


def test_continuous_batching_drains_queue():
    cfg = smoke_config(get_config("granite-3-2b"))
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    cb = ContinuousBatcher(lm, batch=2, max_seq=64).bind_params(params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        cb.submit(r)
    done = cb.run()
    assert len(done) == 5
    assert all(len(r.out) == 5 for r in done)
    assert all(r.done for r in done)


def test_batcher_output_matches_unbatched_decode():
    """A request served through the batcher == plain greedy decode."""
    cfg = smoke_config(get_config("granite-3-2b"))
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    prompt = np.asarray([5, 9, 2, 7], np.int32)

    # reference: manual greedy decode
    cache = lm.init_cache(1, 64, jnp.float32)
    toks = []
    cur = prompt
    pos = 0
    for t in range(len(prompt) + 4):
        inp = (int(cur[pos]) if pos < len(prompt)
               else toks[-1])
        lg, cache = lm.decode_step(params, cache,
                                   {"tokens": jnp.asarray([[inp]])},
                                   jnp.int32(pos), jnp.int32(pos),
                                   mode="local")
        pos += 1
        if pos >= len(prompt):
            toks.append(int(jnp.argmax(lg[0, -1])))
    ref = toks[:4]

    cb = ContinuousBatcher(lm, batch=2, max_seq=64).bind_params(params)
    cb.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = cb.run()
    assert done[0].out == ref
