"""Client-side coherent page cache (PR 10, part 3).

The coherence rule under test: **the partition-map version is the
epoch**. Every cache entry is stamped with its partition's epoch at
fill time; every flip that can change a partition's bytes — a write, a
rebalance step, a heal promotion, a cold-storage restore — bumps that
partition's epoch and ONLY that partition's. So:

  * accounting is exact: a cold read misses once per non-empty
    partition, a warm read hits once per partition and ships ZERO bytes;
  * invalidation is surgical: writing keys owned by one partition
    invalidates that partition alone — its neighbors keep serving from
    cache across the flip;
  * heal invalidates exactly the partitions it promoted or restored;
  * the cache is a bounded LRU over bytes (evicts cold end, refuses
    entries larger than the whole budget, typed error on a non-positive
    budget);
  * concurrent readers racing a live rebalance stay byte-identical —
    the epoch is captured BEFORE the node read, so a racing flip can
    only produce a stale stamp the next lookup rejects, never a
    wrong-bytes hit.
"""
import threading

import numpy as np
import pytest

from repro.core.client import PageCache
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column

N = 600
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))


def make_data(keys, seed=0):
    rng = np.random.default_rng(seed)
    d = {"c0": np.asarray(keys, np.int32)}
    for i in range(1, 8):
        d[f"c{i}"] = rng.integers(-50, 50, len(keys)).astype(np.float32)
    return d


def schema(name="t"):
    return FTable(name, COLS, n_rows=N)


def hash_cluster(k=3, *, cache_bytes=8 * 2**20, seed=0, replicas=1):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 64, N).astype(np.int32)
    words = schema().encode(make_data(keys, seed))
    cl = FarCluster(k, cache_bytes=cache_bytes, replicas=replicas)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, schema(), partitioner="hash", keys=keys)
    cl.table_write(cqp, ct, words)
    return cl, cqp, ct, words, keys


def nonempty(ct):
    return sum(1 for p in ct.parts if p is not None and p.n_rows > 0)


# ---------------------------------------------------------------------------
# the LRU itself
# ---------------------------------------------------------------------------
class TestPageCacheUnit:
    def test_rejects_nonpositive_budget(self):
        with pytest.raises(ValueError):
            PageCache(0)

    def test_lru_evicts_cold_end(self):
        row = np.ones((1, 256), np.float32)         # 1 KiB per entry
        c = PageCache(3 * row.nbytes)
        for i in range(3):
            c.put("t", i, 0, row)
        assert len(c) == 3 and c.evictions == 0
        c.get("t", 0, 0)                            # touch: 0 is now MRU
        c.put("t", 3, 0, row)                       # over budget by one
        assert c.evictions == 1
        assert c.get("t", 1, 0) is None             # 1 was the cold end
        assert c.get("t", 0, 0) is not None
        assert c.cached_bytes <= c.capacity_bytes

    def test_oversized_entry_refused(self):
        c = PageCache(1024)
        c.put("t", 0, 0, np.ones((1, 300), np.float32))   # 1200 B > 1024
        assert len(c) == 0 and c.cached_bytes == 0

    def test_epoch_mismatch_drops_on_sight(self):
        c = PageCache(1 << 20)
        c.put("t", 0, epoch=5, rows=np.ones((2, 2), np.float32))
        assert c.get("t", 0, epoch=6) is None
        assert c.invalidations == 1 and len(c) == 0
        assert c.stats()["misses"] == 1

    def test_hits_are_readonly_private_copies(self):
        c = PageCache(1 << 20)
        src = np.ones((2, 2), np.float32)
        c.put("t", 0, 0, src)
        src[:] = 7.0                                # caller mutates after put
        got = c.get("t", 0, 0)
        np.testing.assert_array_equal(got, np.ones((2, 2), np.float32))
        with pytest.raises(ValueError):
            got[0, 0] = 9.0

    def test_drop_table_is_per_table(self):
        c = PageCache(1 << 20)
        c.put("a", 0, 0, np.ones((1, 4), np.float32))
        c.put("a", 1, 0, np.ones((1, 4), np.float32))
        c.put("b", 0, 0, np.ones((1, 4), np.float32))
        assert c.drop_table("a") == 2
        assert c.get("b", 0, 0) is not None


# ---------------------------------------------------------------------------
# cluster integration: exact accounting + surgical invalidation
# ---------------------------------------------------------------------------
class TestClusterCacheAccounting:
    def test_cold_then_warm_read_exact_counts(self):
        cl, cqp, ct, words, _ = hash_cluster()
        P = nonempty(ct)
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words)
        assert (cqp.cache_misses, cqp.cache_hits) == (P, 0)
        shipped = cqp.bytes_shipped
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words)
        assert (cqp.cache_misses, cqp.cache_hits) == (P, P)
        # a hit moves no bytes: warm read ships NOTHING
        assert cqp.bytes_shipped == shipped
        assert cl.cache.stats()["hits"] == P

    def test_write_invalidates_exactly_the_written_table(self):
        """A full rewrite bumps exactly the written table's non-empty
        partitions; a neighbor table sharing the cache keeps serving
        every one of its partitions from the client copy."""
        cl, cqp, ct, words, keys = hash_cluster()
        ftb = FTable("b", COLS, n_rows=N)
        wb = ftb.encode(make_data(keys, seed=8))
        ctb = cl.alloc_table_mem(cqp, FTable("b", COLS, n_rows=N),
                                 partitioner="hash", keys=keys)
        cl.table_write(cqp, ctb, wb)
        cl.table_read(cqp, ct)                      # warm both tables
        cl.table_read(cqp, ctb)
        pv0 = list(ct.part_version)
        words2 = schema().encode(make_data(keys, seed=9))
        cl.table_write(cqp, ct, words2)
        moved = [i for i, (a, b) in enumerate(zip(pv0, ct.part_version))
                 if a != b]
        live = [i for i, p in enumerate(ct.parts)
                if p is not None and p.n_rows > 0]
        assert sorted(moved) == live                # every written part...
        h0, m0, inv0 = (cqp.cache_hits, cqp.cache_misses,
                        cl.cache.invalidations)
        np.testing.assert_array_equal(
            np.asarray(cl.table_read(cqp, ct)), words2)
        np.testing.assert_array_equal(
            np.asarray(cl.table_read(cqp, ctb)), wb)
        assert cqp.cache_misses - m0 == len(moved)  # ...and ONLY those
        assert cqp.cache_hits - h0 == nonempty(ctb)
        assert cl.cache.invalidations - inv0 == len(moved)

    def test_replicated_table_caches_whole_and_invalidates_on_write(self):
        cl = FarCluster(2, cache_bytes=8 * 2**20)
        cqp = cl.open_connection()
        words = schema().encode(make_data(np.zeros(N, np.int32)))
        ct = cl.alloc_table_mem(cqp, schema(), replicate=True)
        cl.table_write(cqp, ct, words)
        cl.table_read(cqp, ct)
        cl.table_read(cqp, ct)
        assert (cqp.cache_misses, cqp.cache_hits) == (1, 1)
        cl.table_write(cqp, ct, words)              # bump every copy
        cl.table_read(cqp, ct)
        assert cqp.cache_misses == 2
        assert cl.cache.invalidations == 1

    def test_free_table_drops_entries(self):
        cl, cqp, ct, words, _ = hash_cluster()
        cl.table_read(cqp, ct)
        assert len(cl.cache) > 0
        cl.free_table_mem(cqp, ct)
        assert len(cl.cache) == 0

    def test_cache_disabled_by_default(self):
        cl = FarCluster(2)
        assert cl.cache is None


class TestCacheCoherenceUnderFlips:
    def test_rebalance_invalidates_only_moved_partitions(self):
        """Induce skew, warm the cache, rebalance: partitions the plan
        moved re-fetch, the rest hit — and the bytes are identical."""
        cl, cqp, ct, words, keys = hash_cluster(seed=0)
        rng = np.random.default_rng(7)
        owners = ct.co_spec.owners_of(np.arange(64))
        hot = np.arange(64)[owners == 0]
        new_keys = hot[rng.integers(0, len(hot), N)].astype(np.int32)
        new_words = schema().encode(make_data(new_keys, seed=1))
        cl.table_write(cqp, ct, new_words, keys=new_keys)
        cl.table_read(cqp, ct)                      # warm post-skew
        pv0 = list(ct.part_version)
        h0, m0 = cqp.cache_hits, cqp.cache_misses
        plan = cl.rebalance(cqp, ct)
        assert plan.n_moved > 0
        moved = [i for i, (a, b) in enumerate(zip(pv0, ct.part_version))
                 if a != b]
        assert moved
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, new_words)
        assert cqp.cache_misses - m0 >= len(
            [i for i in moved if ct.parts[i] is not None
             and ct.parts[i].n_rows > 0])
        # at least the partitions the plan never touched kept serving
        untouched_hits = cqp.cache_hits - h0
        assert untouched_hits == sum(
            1 for i, p in enumerate(ct.parts)
            if i not in moved and p is not None and p.n_rows > 0)

    def test_heal_invalidates_exactly_promoted_partitions(self):
        cl, cqp, ct, words, _ = hash_cluster(k=3, replicas=2)
        cl.table_read(cqp, ct)
        pv0 = list(ct.part_version)
        cl.fault.kill(0)
        # the warm cache even masks the death: epochs haven't moved, so
        # this read is all hits and never touches the dead node
        h0 = cqp.cache_hits
        np.testing.assert_array_equal(np.asarray(cl.table_read(cqp, ct)),
                                      words)
        assert cqp.cache_hits - h0 == nonempty(ct)
        cl.health.mark_dead(0)                      # detector verdict
        cl.heal(cqp)
        moved = [i for i, (a, b) in enumerate(zip(pv0, ct.part_version))
                 if a != b]
        assert moved and len(moved) < len(ct.parts)
        h0, m0 = cqp.cache_hits, cqp.cache_misses
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words)
        live = [i for i, p in enumerate(ct.parts)
                if p is not None and p.n_rows > 0]
        assert cqp.cache_misses - m0 == len([i for i in moved if i in live])
        assert cqp.cache_hits - h0 == len([i for i in live
                                           if i not in moved])

    def test_concurrent_readers_across_map_flip_byte_identical(self):
        """The splice-harness race: reader threads hammer table_read
        while the main thread rebalances a skewed map. Every read —
        before, during, after the flips — must reassemble the exact
        table; the epoch-captured-before-read rule makes a stale fill
        harmless (rejected next lookup) rather than wrong."""
        cl, cqp, ct, words, keys = hash_cluster(seed=2)
        rng = np.random.default_rng(4)
        owners = ct.co_spec.owners_of(np.arange(64))
        hot = np.arange(64)[owners == 0]
        new_keys = hot[rng.integers(0, len(hot), N)].astype(np.int32)
        new_words = schema().encode(make_data(new_keys, seed=5))
        cl.table_write(cqp, ct, new_words, keys=new_keys)

        stop = threading.Event()
        bad = []

        def reader():
            q = cl.open_connection()
            while not stop.is_set():
                got = np.asarray(cl.table_read(q, ct))
                if not np.array_equal(got, new_words):
                    bad.append(got)
                    return

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        try:
            plan = cl.rebalance(cqp, ct)
        finally:
            stop.set()
            for t in threads:
                t.join()
        assert not bad, "a reader observed torn bytes during the flip"
        assert plan.n_moved > 0
        np.testing.assert_array_equal(
            np.asarray(cl.table_read(cqp, ct)), new_words)
