"""Hypothesis property tests on system invariants.

Each property is an invariant the paper's contract depends on:
  * pipeline results == numpy relational-algebra oracle for any table/query,
  * CTR cipher is involutive and keystream-independent of the data,
  * pool allocator never double-allocates and free fully reclaims,
  * partial-softmax merge == full softmax for any split of the KV sequence,
  * select-then-group == group of selected rows.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import operators as op
from repro.core.table import FTable, Column
from repro.kernels import ops as kops
from repro.kernels import ref as kref

_settings = dict(deadline=None, max_examples=25,
                 suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# selection pipeline == numpy oracle
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(
    n=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
    opcode=st.sampled_from(["<", "<=", ">", ">=", "==", "!="]),
    thresh=st.floats(-2, 2, allow_nan=False),
)
def test_selection_matches_numpy(n, seed, opcode, thresh):
    rng = np.random.default_rng(seed)
    a = 4
    table = rng.normal(size=(n, a)).astype(np.float32)
    sel_ops = np.zeros(a, np.int32)
    sel_vals = np.zeros(a, np.float32)
    sel_ops[1] = op.OPS[opcode]
    sel_vals[1] = np.float32(thresh)
    proj = np.ones(a, np.float32)
    packed, count = kops.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    col = table[:, 1]
    t = np.float32(thresh)
    npmask = {"<": col < t, "<=": col <= t, ">": col > t, ">=": col >= t,
              "==": col == t, "!=": col != t}[opcode]
    assert int(count) == int(npmask.sum())
    np.testing.assert_allclose(np.asarray(packed)[: int(count)],
                               table[npmask], rtol=1e-6)


# ---------------------------------------------------------------------------
# group-by == dict oracle, any key distribution / bucket count
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(
    n=st.integers(1, 800),
    card=st.integers(1, 300),
    nb=st.sampled_from([16, 64, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_matches_dict_oracle(n, card, nb, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(-card, card, size=n).astype(np.int32)
    vals = rng.normal(size=(n, 2)).astype(np.float32)
    got = kops.group_aggregate_full(jnp.asarray(keys), jnp.asarray(vals),
                                    n_buckets=nb)
    exact = kref.group_aggregate_exact(keys, vals)
    assert set(got) == set(exact)
    for k in exact:
        assert got[k][0] == exact[k][0]
        np.testing.assert_allclose(got[k][1], exact[k][1],
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# CTR cipher properties
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(
    n=st.integers(1, 5000),
    k0=st.integers(0, 2**32 - 1),
    k1=st.integers(0, 2**32 - 1),
    nonce=st.integers(0, 2**32 - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_crypt_involutive(n, k0, k1, nonce, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
    key = np.array([k0, k1], np.uint32)
    enc = kops.crypt(jnp.asarray(data), key, nonce)
    dec = kops.crypt(enc, key, nonce)
    np.testing.assert_array_equal(np.asarray(dec), data)


@settings(**_settings)
@given(seed=st.integers(0, 2**31 - 1), nonce=st.integers(0, 2**32 - 1))
def test_crypt_keystream_data_independent(seed, nonce):
    """CTR mode: keystream = E(key, ctr) independent of plaintext."""
    rng = np.random.default_rng(seed)
    d1 = rng.integers(0, 1 << 32, size=256, dtype=np.uint32)
    d2 = rng.integers(0, 1 << 32, size=256, dtype=np.uint32)
    key = np.array([7, 9], np.uint32)
    s1 = np.asarray(kops.crypt(jnp.asarray(d1), key, nonce)) ^ d1
    s2 = np.asarray(kops.crypt(jnp.asarray(d2), key, nonce)) ^ d2
    np.testing.assert_array_equal(s1, s2)


# ---------------------------------------------------------------------------
# pool allocator invariants
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(
    ops_seq=st.lists(st.tuples(st.booleans(), st.integers(1, 40)),
                     min_size=1, max_size=30),
    n_shards=st.sampled_from([1, 2, 4]),
)
def test_pool_allocator_invariants(ops_seq, n_shards):
    from repro.core.pool import FarPool
    pool = FarPool(64 * 2**20, n_shards=n_shards)   # 32 pages
    live: list = []
    total_pages = pool.n_pages
    for is_alloc, size_pages in ops_seq:
        if is_alloc:
            rows = size_pages * pool.page_words // 8
            ft = FTable("t", tuple(Column(f"c{i}") for i in range(8)),
                        n_rows=rows)
            try:
                pool.alloc_table(ft)
                live.append(ft)
            except MemoryError:
                assert pool.free_pages < size_pages
        elif live:
            pool.free_table(live.pop())
        # invariant: no page owned twice
        owned = [p for f in live for p in f.pages]
        assert len(owned) == len(set(owned))
        assert len(owned) + pool.free_pages == total_pages
    for f in live:
        pool.free_table(f)
    assert pool.free_pages == total_pages


# ---------------------------------------------------------------------------
# far-KV: any split of the sequence merges to the full softmax
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_shards=st.integers(1, 6),
    s=st.integers(8, 256),
)
def test_partial_merge_any_split(seed, n_shards, s):
    rng = np.random.default_rng(seed)
    b, hq, hkv, d = 2, 4, 2, 32
    # align shard size upward so splits cover s
    per = -(-s // n_shards)
    s_pad = per * n_shards
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s_pad, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s_pad, hkv, d)).astype(np.float32)
    lengths = rng.integers(1, s + 1, size=b).astype(np.int32)
    parts = []
    for i in range(n_shards):
        loc = np.clip(lengths - i * per, 0, per).astype(np.int32)
        parts.append(kref.decode_attention(
            jnp.asarray(q), jnp.asarray(k[:, i * per:(i + 1) * per]),
            jnp.asarray(v[:, i * per:(i + 1) * per]), jnp.asarray(loc)))
    merged = kref.merge_partials(parts)
    full = kref.full_attention_oracle(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# operator-pipeline composition law
# ---------------------------------------------------------------------------
@settings(**_settings)
@given(seed=st.integers(0, 2**31 - 1), card=st.integers(1, 30),
       thresh=st.floats(0, 1, allow_nan=False))
def test_select_then_group_equals_group_of_selected(seed, card, thresh):
    """farview_request(Select+GroupBy) == oracle(group(select(rows)))."""
    from repro.core.client import (FViewNode, open_connection,
                                   alloc_table_mem, table_write,
                                   farview_request, merge_group_partials)
    rng = np.random.default_rng(seed)
    n = 512
    node = FViewNode(16 * 2**20)
    qp = open_connection(node)
    ft = FTable("t", (Column("k", "i32"), Column("v", "f32"),
                      Column("w", "f32")), n_rows=n)
    alloc_table_mem(qp, ft)
    data = {"k": rng.integers(0, card, n).astype(np.int32),
            "v": rng.random(n).astype(np.float32),
            "w": rng.random(n).astype(np.float32)}
    table_write(qp, ft, ft.encode(data))
    pipe = (op.Select((op.Predicate("v", "<", float(thresh)),)),
            op.GroupBy("k", ("w",), n_buckets=64))
    res = farview_request(qp, ft, pipe)
    merged = merge_group_partials(ft, pipe, [res]).groups
    mask = data["v"] < np.float32(thresh)
    exact: dict = {}
    for kk, ww in zip(data["k"][mask], data["w"][mask]):
        e = exact.setdefault(int(kk), [0, 0.0])
        e[0] += 1
        e[1] += float(ww)
    assert set(merged) == set(exact)
    for kk in exact:
        assert merged[kk][0] == exact[kk][0]
        np.testing.assert_allclose(np.asarray(merged[kk][1]).ravel()[0],
                                   exact[kk][1], rtol=1e-3, atol=1e-3)
