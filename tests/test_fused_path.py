"""The fused request path (core/pipeline.py CompiledPipeline).

(a) the single jitted executable's results are byte-identical to the
    kernels/ref.py reference for select/project, group-by, crypt and join
    pipelines;
(b) cache regression: a repeated pipeline signature performs exactly one
    trace (CompiledPipeline.traces counts trace-time entries);
(c) batched multi-QP dispatch (one stacked executable per scheduling
    round) preserves per-client results and fair accounting;
(d) results are lazy: finalize() is the sync point and settles byte
    accounting.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, submit_request, table_write)
from repro.core.pipeline import clear_cache, compile_pipeline
from repro.core.table import FTable, Column
from repro.kernels import ref


def word_table(qp, name, n=1024, seed=0, card=0):
    rng = np.random.default_rng(seed)
    cols = tuple(Column(f"c{i}", "i32" if (i == 0 and card) else "f32")
                 for i in range(8))
    ft = FTable(name, cols, n_rows=n)
    alloc_table_mem(qp, ft)
    data = {}
    for i in range(8):
        if i == 0 and card:
            data["c0"] = rng.integers(0, card, n).astype(np.int32)
        else:
            data[f"c{i}"] = rng.normal(size=n).astype(np.float32)
    words = ft.encode(data)
    table_write(qp, ft, words)
    return ft, data, words


class TestRefParity:
    """Fused executable output == kernels/ref.py oracle, byte for byte."""

    def setup_method(self):
        self.node = FViewNode(32 * 2**20)
        self.qp = open_connection(self.node)

    def test_select_project(self):
        ft, data, words = word_table(self.qp, "sp")
        pipe = (op.Project(("c1", "c4")),
                op.Select((op.Predicate("c2", "<", 0.3),
                           op.Predicate("c5", ">", -0.8))))
        res = farview_request(self.qp, ft, pipe).finalize()
        sel_ops = np.zeros(8, np.int32)
        sel_vals = np.zeros(8, np.float32)
        sel_ops[2], sel_vals[2] = op.OPS["<"], 0.3
        sel_ops[5], sel_vals[5] = op.OPS[">"], -0.8
        proj = np.zeros(8, np.float32)
        proj[[1, 4]] = 1.0
        exp_rows, exp_count = ref.select_project(
            jnp.asarray(words), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
            jnp.asarray(proj))
        assert res.count == int(exp_count)
        np.testing.assert_array_equal(np.asarray(res.rows),
                                      np.asarray(exp_rows))
        assert res.shipped_bytes == res.count * 2 * 4

    def test_group_by(self):
        ft, data, words = word_table(self.qp, "gb", card=19)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),
                op.GroupBy("c0", ("c1", "c2"), n_buckets=256))
        res = farview_request(self.qp, ft, pipe).finalize()
        # oracle: same masking contract, then the ref kernel
        keys = np.rint(words[:, 0]).astype(np.int32)
        vals = words[:, [1, 2]].astype(np.float32)
        m = data["c1"] < 0.0
        keys = np.where(m, keys, ref.KEY_SENTINEL + 1)
        vals = np.where(m[:, None], vals, 0)
        exp = ref.group_aggregate(jnp.asarray(keys), jnp.asarray(vals), 256)
        for k in ("bucket_keys", "count", "sum", "min", "max"):
            np.testing.assert_array_equal(np.asarray(res.groups[k]),
                                          np.asarray(exp[k]))
        ovf = np.asarray(exp["overflow_mask"]).astype(bool)
        exp_ovf_keys = keys[ovf]
        keep = exp_ovf_keys != ref.KEY_SENTINEL + 1
        np.testing.assert_array_equal(res.groups["ovf_keys"],
                                      exp_ovf_keys[keep])
        np.testing.assert_array_equal(res.groups["ovf_vals"],
                                      vals[ovf][keep])

    def test_crypt_pre_and_post(self):
        ft, data, words = word_table(self.qp, "cr")
        key, nonce = (7, 13), 21
        plain_u32 = words.astype(np.float32).reshape(-1).view(np.uint32)
        enc = np.asarray(ref.ctr_crypt(jnp.asarray(plain_u32),
                                       jnp.asarray(key, jnp.uint32), nonce))
        table_write(self.qp, ft,
                    enc.view(np.float32).reshape(words.shape))
        pipe = (op.Crypt(key=key, nonce=nonce, when="pre"),
                op.Select((op.Predicate("c3", ">=", 0.1),)))
        res = farview_request(self.qp, ft, pipe).finalize()
        sel_ops = np.zeros(8, np.int32)
        sel_vals = np.zeros(8, np.float32)
        sel_ops[3], sel_vals[3] = op.OPS[">="], 0.1
        exp_rows, exp_count = ref.select_project(
            jnp.asarray(words), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
            jnp.ones(8, jnp.float32))
        assert res.count == int(exp_count)
        np.testing.assert_array_equal(np.asarray(res.rows),
                                      np.asarray(exp_rows))
        # post-encrypt: response must decrypt back to the plain projection
        table_write(self.qp, ft, words)
        pipe2 = (op.Project(("c0",)),
                 op.Crypt(key=(9, 9), nonce=3, when="post"))
        res2 = farview_request(self.qp, ft, pipe2).finalize()
        resp = np.asarray(res2.rows).reshape(-1).view(np.uint32)
        dec = np.asarray(ref.ctr_crypt(jnp.asarray(resp),
                                       jnp.asarray((9, 9), jnp.uint32), 3))
        got = dec.view(np.float32).reshape(np.asarray(res2.rows).shape)
        proj = np.zeros(8, np.float32)
        proj[0] = 1.0
        exp_rows2, _ = ref.select_project(
            jnp.asarray(words), jnp.zeros(8, jnp.int32),
            jnp.zeros(8, jnp.float32), jnp.asarray(proj))
        np.testing.assert_array_equal(got, np.asarray(exp_rows2))

    def test_n_valid_tail_masking_groups(self):
        """run_pages with n_valid < n_rows: masked tail rows must not leak
        a phantom group (drop_key filters them at merge)."""
        from repro.core.offload import _merge
        ft, data, words = word_table(self.qp, "nv", n=64, card=8)
        pipe = (op.Distinct(("c0",), n_buckets=32),)
        cp = compile_pipeline(ft, pipe)
        res = cp.run_pages(self.node.pool.buf, ft.pages, 40,
                           n_rows=ft.n_rows, row_words=ft.row_words)
        res.finalize()
        assert res.groups["drop_key"] is not None
        merged = _merge(ft, pipe, [res]).groups
        assert set(merged) == set(np.unique(data["c0"][:40]).tolist())

    def test_duplicate_build_keys_rejected(self):
        """The uniqueness contract must hold on the jitted path too (the
        traced hash_join cannot check it; _as_build does, eagerly)."""
        ft, _, _ = word_table(self.qp, "p", n=256, card=8)
        pipe = (op.JoinSmall(probe_key="c0", build_table="b",
                             build_key="k", build_cols=("v",)),)
        cp = compile_pipeline(ft, pipe)
        dup = (np.asarray([1, 1, 2], np.int32),
               np.ones((3, 1), np.float32))
        with pytest.raises(ValueError, match="unique"):
            cp.run_pages(self.node.pool.buf, ft.pages, ft.n_rows,
                         build=dup, n_rows=ft.n_rows,
                         row_words=ft.row_words)

    def test_join(self):
        ft, data, words = word_table(self.qp, "probe", card=64)
        build = FTable("build", (Column("k", "i32"), Column("v")), n_rows=40)
        alloc_table_mem(self.qp, build)
        rng = np.random.default_rng(9)
        bk = rng.permutation(64)[:40].astype(np.int32)
        bv = rng.random(40).astype(np.float32)
        table_write(self.qp, build, build.encode({"k": bk, "v": bv}))
        pipe = (op.JoinSmall(probe_key="c0", build_table="build",
                             build_key="k", build_cols=("v",)),)
        res = farview_request(self.qp, ft, pipe).finalize()
        # oracle: ref.hash_join + the pipeline's join-as-extra-columns
        # contract through ref.select_project
        pk = np.rint(words[:, 0]).astype(np.int32)
        joined, hit = ref.hash_join(pk, bk, bv[:, None])
        work = np.concatenate(
            [words, joined, hit[:, None].astype(np.float32)], axis=1)
        sel_ops = np.concatenate([np.zeros(9, np.int32),
                                  [op.OPS["=="]]]).astype(np.int32)
        sel_vals = np.concatenate([np.zeros(9, np.float32),
                                   [1.0]]).astype(np.float32)
        proj = np.concatenate([np.ones(9, np.float32),
                               [0.0]]).astype(np.float32)
        exp_rows, exp_count = ref.select_project(
            jnp.asarray(work), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
            jnp.asarray(proj))
        assert res.count == int(exp_count) == int(hit.sum())
        np.testing.assert_array_equal(np.asarray(res.rows),
                                      np.asarray(exp_rows))


class TestCacheRegression:
    def test_repeated_signature_single_trace(self):
        clear_cache()
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft, _, _ = word_table(qp, "t", n=512)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        farview_request(qp, ft, pipe).finalize()
        cp = compile_pipeline(ft, pipe)
        assert cp.traces == 1            # exactly one trace for the warm-up
        for _ in range(4):
            farview_request(qp, ft, pipe).finalize()
        assert cp.traces == 1            # zero retraces on repeats

    def test_same_layout_shares_executable(self):
        """Two same-layout tables (different names) share one executable —
        the property the batched scheduler relies on."""
        clear_cache()
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft1, _, _ = word_table(qp, "a", n=512, seed=1)
        ft2, _, _ = word_table(qp, "b", n=512, seed=2)
        pipe = (op.Select((op.Predicate("c2", ">", 0.0),)),)
        p1 = compile_pipeline(ft1, pipe)
        p2 = compile_pipeline(ft2, pipe)
        assert p1 is p2


class TestBatchedDispatch:
    def test_batched_preserves_per_client_results(self):
        clear_cache()
        node = FViewNode(64 * 2**20, n_regions=4)
        qps, fts, wordss = [], [], []
        for i in range(4):
            qp = open_connection(node)
            ft, _, words = word_table(qp, f"t{i}", n=768, seed=10 + i)
            qps.append(qp)
            fts.append(ft)
            wordss.append(words)
        pipe = (op.Select((op.Predicate("c1", "<", 0.25),)),)
        pends = [submit_request(qp, ft, pipe)
                 for qp, ft in zip(qps, fts)]
        assert all(p.result is None for p in pends)   # queued, not dispatched
        node.flush()
        sel_ops = np.zeros(8, np.int32)
        sel_vals = np.zeros(8, np.float32)
        sel_ops[1], sel_vals[1] = op.OPS["<"], 0.25
        for p, words in zip(pends, wordss):
            res = p.wait()
            exp_rows, exp_count = ref.select_project(
                jnp.asarray(words), jnp.asarray(sel_ops),
                jnp.asarray(sel_vals), jnp.ones(8, jnp.float32))
            assert res.count == int(exp_count)
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(exp_rows))
        assert all(qp.requests == 1 for qp in qps)
        assert node.pool.stats.requests == 4

    def test_batched_rounds_do_not_retrace(self):
        clear_cache()
        node = FViewNode(64 * 2**20, n_regions=3)
        qps, fts = [], []
        for i in range(3):
            qp = open_connection(node)
            ft, _, _ = word_table(qp, f"t{i}", n=512, seed=i)
            qps.append(qp)
            fts.append(ft)
        pipe = (op.Distinct(("c0",), n_buckets=64),)
        for qp, ft in zip(qps, fts):
            submit_request(qp, ft, pipe)
        node.settle()
        cp = compile_pipeline(fts[0], pipe)
        warm = cp.traces
        for _ in range(3):
            for qp, ft in zip(qps, fts):
                submit_request(qp, ft, pipe)
            node.settle()
        assert cp.traces == warm         # stacked dispatch fully cached

    def test_permuted_layouts_do_not_coalesce(self):
        """Same-shaped tables with different column orders compile to
        different programs — they must not share a stacked dispatch."""
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        rng = np.random.default_rng(7)
        k = rng.integers(0, 8, 256).astype(np.int32)
        v = rng.normal(size=256).astype(np.float32)
        ft1 = FTable("kv", (Column("k", "i32"), Column("v")), n_rows=256)
        ft2 = FTable("vk", (Column("v"), Column("k", "i32")), n_rows=256)
        alloc_table_mem(qp1, ft1)
        alloc_table_mem(qp2, ft2)
        table_write(qp1, ft1, ft1.encode({"k": k, "v": v}))
        table_write(qp2, ft2, ft2.encode({"k": k, "v": v}))
        pipe = (op.Select((op.Predicate("k", "==", 3.0),)),)
        p1 = submit_request(qp1, ft1, pipe)
        p2 = submit_request(qp2, ft2, pipe)
        node.flush()
        exp = int((k == 3).sum())
        assert p1.wait().count == exp
        assert p2.wait().count == exp

    def test_dispatch_error_isolated_per_group(self):
        """One group's dispatch failure must not discard the round's other
        requests; the error surfaces on the failing request only."""
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        ft1, d1, _ = word_table(qp1, "ok", n=512, seed=1)
        probe, _, _ = word_table(qp2, "probe", n=512, seed=2, card=16)
        bad_build = FTable("dup", (Column("k", "i32"), Column("v")),
                           n_rows=4)
        alloc_table_mem(qp2, bad_build)
        table_write(qp2, bad_build, bad_build.encode(
            {"k": np.asarray([1, 1, 2, 3], np.int32),
             "v": np.ones(4, np.float32)}))
        good = submit_request(qp1, ft1,
                              (op.Select((op.Predicate("c1", "<", 0.0),)),))
        bad = submit_request(qp2, probe,
                             (op.JoinSmall(probe_key="c0",
                                           build_table="dup",
                                           build_key="k",
                                           build_cols=("v",)),))
        with pytest.raises(ValueError, match="unique"):
            node.flush()
        assert good.wait().count == int((d1["c1"] < 0).sum())
        with pytest.raises(ValueError, match="unique"):
            bad.wait()

    def test_counter_read_survives_foreign_dispatch_error(self):
        """An innocent QPair counter read must not raise another client's
        dispatch error; successful responses still settle."""
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        ft1, _, _ = word_table(qp1, "ok", n=512, seed=1)
        probe, _, _ = word_table(qp2, "probe", n=512, seed=2, card=16)
        dup = FTable("dup2", (Column("k", "i32"), Column("v")), n_rows=4)
        alloc_table_mem(qp2, dup)
        table_write(qp2, dup, dup.encode(
            {"k": np.asarray([5, 5, 6, 7], np.int32),
             "v": np.ones(4, np.float32)}))
        submit_request(qp1, ft1, (op.Project(("c2",)),))
        bad = submit_request(qp2, probe,
                             (op.JoinSmall(probe_key="c0",
                                           build_table="dup2",
                                           build_key="k",
                                           build_cols=("v",)),))
        assert qp1.bytes_shipped == ft1.n_rows * 4     # no raise, settled
        with pytest.raises(ValueError, match="unique"):
            bad.wait()

    def test_round_robin_fair_share(self):
        """Two queued requests from one QPair are served in different
        scheduling rounds; one from each QPair coalesces per round."""
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        ft1, d1, _ = word_table(qp1, "x", n=512, seed=3)
        ft2, d2, _ = word_table(qp2, "y", n=512, seed=4)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        a1 = submit_request(qp1, ft1, pipe)
        a2 = submit_request(qp1, ft1, pipe)   # same client, second round
        b1 = submit_request(qp2, ft2, pipe)
        node.flush()
        for pend, d in ((a1, d1), (a2, d1), (b1, d2)):
            assert pend.result.finalize().count == int((d["c1"] < 0).sum())
        assert qp1.requests == 2 and qp2.requests == 1


class TestLazyResults:
    def test_finalize_is_the_sync_point(self):
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft, data, _ = word_table(qp, "t", n=512)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        res = farview_request(qp, ft, pipe)
        assert res._raw is not None           # nothing materialized yet
        assert qp._bytes_shipped == 0          # shipped accounting deferred
        n = res.count                          # first scalar access syncs
        assert res._raw is None
        assert n == int((data["c1"] < 0).sum())
        assert qp.bytes_shipped == res.shipped_bytes
        res.finalize()                         # idempotent
        assert node.pool.stats.bytes_shipped == res.shipped_bytes

    def test_settle_via_qpair_counters(self):
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft, data, _ = word_table(qp, "t", n=512)
        pipe = (op.Project(("c2",)),)
        submit_request(qp, ft, pipe)           # queued only
        assert qp.bytes_shipped == ft.n_rows * 4   # settles queue + inflight
        assert qp.bytes_read_pool == ft.n_bytes

    def test_finalized_results_leave_inflight(self):
        """Caller-finalized responses must not pin device memory on the
        node forever."""
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft, _, _ = word_table(qp, "t", n=512)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        for _ in range(10):
            farview_request(qp, ft, pipe).finalize()
        assert node._inflight == []

    def test_smart_addressing_crypt_read_accounting(self):
        """A pre-decrypt forces full-row gathers; read accounting must
        match (plain smart addressing stays column-granular)."""
        from repro.kernels import ops as kops
        node = FViewNode(32 * 2**20)
        qp = open_connection(node)
        ft, data, words = word_table(qp, "t", n=512)
        sa = farview_request(qp, ft, (op.SmartAddress(("c3",)),)).finalize()
        assert sa.read_bytes == ft.n_rows * 4            # 1 column
        key, nonce = (3, 5), 11
        u32 = words.astype(np.float32).reshape(-1).view(np.uint32)
        enc = np.asarray(kops.crypt(jnp.asarray(u32),
                                    np.asarray(key, np.uint32), nonce))
        table_write(qp, ft, enc.view(np.uint32).astype(np.uint32)
                    .view(np.float32).reshape(words.shape))
        pipe = (op.Crypt(key=key, nonce=nonce, when="pre"),
                op.SmartAddress(("c3",)))
        res = farview_request(qp, ft, pipe).finalize()
        assert res.read_bytes == ft.n_bytes              # full rows read
        got = np.asarray(res.rows)[: res.count, 0]
        np.testing.assert_array_equal(got, data["c3"])
