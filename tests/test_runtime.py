"""Runtime fault-tolerance tests: restore-on-start, NaN containment,
checkpoint cadence, elastic restart, loss actually decreases."""
import tempfile

import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models.lm import LM
from repro.runtime.train_loop import TrainLoop


def make_loop(d, total=6, every=2, seed=0, vocab_seq=(128, 32), lr=1e-3,
              batch=2):
    cfg = smoke_config(get_config("xlstm-125m")).replace(
        n_layers=4, d_model=64, n_heads=2, head_dim=32, vocab=vocab_seq[0])
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=2, total_steps=total,
                       checkpoint_dir=d, checkpoint_every=every, seed=seed)
    lm = LM(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=vocab_seq[1],
                         global_batch=batch, seed=seed)
    return TrainLoop(lm, tcfg, pipe)


def test_loop_runs_and_checkpoints():
    with tempfile.TemporaryDirectory() as d:
        loop = make_loop(d, total=6, every=2)
        stats = loop.run(6)
        assert stats.steps_done == 6
        assert loop.ckpt.latest_step() == 5
        assert all(np.isfinite(l) for l in stats.losses)


def test_restore_on_restart_continues():
    with tempfile.TemporaryDirectory() as d:
        loop1 = make_loop(d, total=4, every=2)
        loop1.run(4)
        # "crash" after step 4; a new loop object restarts from step 3+1
        loop2 = make_loop(d, total=8, every=2)
        stats2 = loop2.run(8)
        assert stats2.restarts == 1
        assert stats2.steps_done == 4          # only steps 4..7 re-run
        assert loop2.ckpt.latest_step() == 7


def test_nan_containment():
    with tempfile.TemporaryDirectory() as d:
        loop = make_loop(d, total=6, every=2)
        stats = loop.run(6, fail_at_step=3)
        assert stats.nan_events == 1
        assert stats.steps_done >= 5           # recovered and finished
        assert loop.ckpt.latest_step() == 5
        assert all(np.isfinite(l) for l in stats.losses)


class _BigramPipeline(TokenPipeline):
    """Deterministic next = cur+1 (mod vocab) stream: learnable to ~0 CE."""

    def batch_at(self, step):
        rng = np.random.default_rng(step)
        b, s = self.local_batch, self.seq_len
        start = rng.integers(0, self.vocab, size=(b, 1))
        toks = (start + np.arange(s + 1)[None, :]) % self.vocab
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def test_loss_decreases():
    """The loop actually learns: deterministic bigram CE drops sharply."""
    from repro.configs.base import TrainConfig
    with tempfile.TemporaryDirectory() as d:
        cfg = smoke_config(get_config("granite-3-2b")).replace(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
            d_ff=128, vocab=64)
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                           total_steps=60, checkpoint_dir=d,
                           checkpoint_every=999, seed=0)
        pipe = _BigramPipeline(vocab=64, seq_len=16, global_batch=4, seed=0)
        loop = TrainLoop(LM(cfg), tcfg, pipe)
        stats = loop.run(60)
        first = np.mean(stats.losses[:5])
        last = np.mean(stats.losses[-5:])
        assert stats.nan_events == 0
        assert last < first - 1.0, (first, last)


def test_elastic_restart_same_data_order():
    """Restarted loop sees the same batches a continuous run would (the
    elastic re-mesh contract needs only shardings to change, not data)."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        cont = make_loop(d1, total=8, every=100)
        s_cont = cont.run(8)
        part1 = make_loop(d2, total=4, every=2)
        part1.run(4)
        part2 = make_loop(d2, total=8, every=2)
        s_part = part2.run(8)
        # last-step losses must agree to float tolerance
        assert abs(s_cont.losses[-1] - s_part.losses[-1]) < 5e-3
