"""FarCluster scatter-gather (PR 3 tentpole).

The contract under test: a pool sharded across N FViewNodes answers every
Farview verb BYTE-IDENTICALLY to one node holding the whole table —

(a) selection / projection / smart addressing: survivors splice back in
    original row order for every partitioner (range, hash, skew);
(b) group-aggregate and distinct: partial aggregates merge exactly
    (integer-valued data so float sums are order-insensitive);
(c) regex: per-partition masks scatter to original row positions;
(d) crypt: pre-decrypt works on arbitrary row subsets (keystream addressed
    by original offsets) and post-encrypted responses are spliced and
    re-encrypted at merged positions;
(e) join: replicated (broadcast) build + partitioned probe;
(f) per-node scheduling still coalesces: K cluster clients sharing a
    pipeline cost each node one stacked dispatch per round;
(g) read/shipped accounting aggregates exactly (no double counting);
plus merge_group_partials edge cases (empty partition, single group,
all-rows-filtered) and close_connection request-cancellation coverage.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FarviewError, FViewNode, alloc_table_mem,
                               close_connection, farview_request,
                               merge_group_partials, open_connection,
                               submit_request, table_write)
from repro.core.cluster import FarCluster
from repro.core.pipeline import PipelineResult
from repro.core.table import FTable, Column, string_table
from repro.distributed.sharding import partition_rows
from repro.kernels import ref as kref

N = 700
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
KEY, NONCE = (11, 22), 7


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 8):
        # integer-valued floats: group sums are exact under ANY merge order,
        # so "byte-identical" is meaningful for aggregates too
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return d


def schema(name="t"):
    return FTable(name, COLS, n_rows=N)


def encrypt_words(words, key=KEY, nonce=NONCE):
    flat = jnp.asarray(np.asarray(words, np.float32).reshape(-1))
    enc = kref.ctr_crypt(flat.view(jnp.uint32), jnp.asarray(key, jnp.uint32),
                         nonce)
    return np.asarray(enc).view(np.float32).reshape(np.shape(words))


def solo_run(pipe, words, build=None):
    node = FViewNode(64 * 2**20)
    qp = open_connection(node)
    if build is not None:
        bft, bwords = build
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        alloc_table_mem(qp, b)
        table_write(qp, b, bwords)
    ft = schema()
    alloc_table_mem(qp, ft)
    table_write(qp, ft, words)
    return farview_request(qp, ft, pipe).finalize()


def cluster_run(pipe, words, k, partitioner, build=None, keys=None):
    cl = FarCluster(k, partitioner=partitioner)
    cqp = cl.open_connection()
    if build is not None:
        bft, bwords = build
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        cb = cl.alloc_table_mem(cqp, b, replicate=True)
        cl.table_write(cqp, cb, bwords)
    ct = cl.alloc_table_mem(cqp, schema(), keys=keys)
    cl.table_write(cqp, ct, words)
    res = cl.farview_request(cqp, ct, pipe).finalize()
    return res, cl, cqp, ct


def assert_rows_identical(res, ref):
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes
    assert res.read_bytes == ref.read_bytes


PARTITIONERS = ("range", "hash", "skew")
NODE_COUNTS = (1, 2, 3)


class TestByteIdentity:
    """Cluster vs solo for every operator kind x partitioner x node count."""

    @pytest.mark.parametrize("partitioner", PARTITIONERS)
    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_selection(self, data, partitioner, k):
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),
                           op.Predicate("c2", ">", -20.0))),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        keys = data["c0"] if partitioner != "range" else None
        res, *_ = cluster_run(pipe, words, k, partitioner, keys=keys)
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("partitioner", PARTITIONERS)
    def test_projection(self, data, partitioner):
        pipe = (op.Project(("c1", "c3")),
                op.Select((op.Predicate("c1", ">", 0.0),)))
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        res, *_ = cluster_run(pipe, words, 3, partitioner,
                              keys=data["c0"] if partitioner != "range"
                              else None)
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_smart_addressing(self, data, k):
        """Column-granular reads per partition; read bytes stay exact."""
        pipe = (op.SmartAddress(("c2", "c5")),
                op.Select((op.Predicate("c2", "<", 10.0),)))
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        res, *_ = cluster_run(pipe, words, k, "range")
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("partitioner", PARTITIONERS)
    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_group_aggregate(self, data, partitioner, k):
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_run(pipe, words)]).groups
        keys = data["c0"] if partitioner != "range" else None
        res, *_ = cluster_run(pipe, words, k, partitioner, keys=keys)
        got = res.groups
        assert set(got) == set(ref)
        for key in ref:
            rc, rs, rmn, rmx = ref[key]
            cc, cs, cmn, cmx = got[key]
            assert rc == cc
            np.testing.assert_array_equal(np.asarray(rs), np.asarray(cs))
            np.testing.assert_array_equal(np.asarray(rmn), np.asarray(cmn))
            np.testing.assert_array_equal(np.asarray(rmx), np.asarray(cmx))

    def test_group_aggregate_oracle(self, data):
        """Cluster group-by agrees with the numpy exact-group oracle."""
        pipe = (op.GroupBy("c0", ("c1",), n_buckets=128),)
        words = schema().encode(data)
        res, *_ = cluster_run(pipe, words, 3, "hash", keys=data["c0"])
        for key in np.unique(data["c0"]):
            m = data["c0"] == key
            cnt, s, mn, mx = res.groups[int(key)]
            assert cnt == int(m.sum())
            np.testing.assert_array_equal(np.asarray(s).reshape(()),
                                          data["c1"][m].sum())

    @pytest.mark.parametrize("partitioner", ("range", "hash"))
    def test_distinct(self, data, partitioner):
        pipe = (op.Distinct(("c0",), n_buckets=128),)
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_run(pipe, words)]).groups
        res, *_ = cluster_run(pipe, words, 3, partitioner,
                              keys=data["c0"] if partitioner == "hash"
                              else None)
        assert set(res.groups) == set(ref) == set(np.unique(data["c0"]))

    @pytest.mark.parametrize("partitioner", PARTITIONERS)
    def test_crypt_pre_words(self, data, partitioner):
        """Encrypted-at-rest table: every partition decrypts with the
        keystream slice of its ORIGINAL row offsets."""
        pipe = (op.Crypt(key=KEY, nonce=NONCE, when="pre"),
                op.Select((op.Predicate("c1", "<", 0.0),)))
        enc = encrypt_words(schema().encode(data))
        ref = solo_run(pipe, enc)
        assert ref.count > 0
        keys = data["c0"] if partitioner != "range" else None
        res, *_ = cluster_run(pipe, enc, 3, partitioner, keys=keys)
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("k", (2, 3))
    def test_crypt_post_response(self, data, k):
        """Per-node encrypted responses splice + re-encrypt to the exact
        single-node ciphertext (zero tail included: it carries keystream)."""
        pipe = (op.Select((op.Predicate("c2", ">", 0.0),)),
                op.Crypt(key=(3, 9), nonce=4, when="post"))
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        res, *_ = cluster_run(pipe, words, k, "hash", keys=data["c0"])
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("partitioner", ("range", "hash"))
    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_join_partitioned_probe(self, data, partitioner, k):
        rng = np.random.default_rng(3)
        bft = FTable("cust", (Column("k", "i32"), Column("v")), n_rows=40)
        bwords = bft.encode({"k": rng.permutation(64)[:40].astype(np.int32),
                             "v": rng.integers(0, 99, 40).astype(np.float32)})
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        jdata = dict(data)
        jdata["c0"] = rng.integers(0, 64, N).astype(np.int32)
        words = schema().encode(jdata)
        ref = solo_run(pipe, words, build=(bft, bwords))
        keys = jdata["c0"] if partitioner != "range" else None
        res, *_ = cluster_run(pipe, words, k, partitioner,
                              build=(bft, bwords), keys=keys)
        assert_rows_identical(res, ref)


class TestByteIdentityStrings:
    STRS = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
            b"errr", b"the error is late"]

    def _strings(self, n=300, width=24, seed=5):
        rng = np.random.default_rng(seed)
        strs = [self.STRS[j] for j in rng.integers(0, len(self.STRS), n)]
        return string_table("s", strs, width)

    def _solo(self, pipe, ft, mat, lens):
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        part = FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                      str_width=ft.str_width)
        alloc_table_mem(qp, part)
        return farview_request(qp, part, pipe,
                               strings=mat, lengths=lens).finalize()

    def _cluster(self, pipe, ft, mat, lens, k, partitioner):
        cl = FarCluster(k, partitioner=partitioner)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(
            cqp, FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                        str_width=ft.str_width))
        return cl.farview_request(cqp, ct, pipe,
                                  strings=mat, lengths=lens).finalize()

    @pytest.mark.parametrize("partitioner", PARTITIONERS)
    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_regex_mask_scatter(self, partitioner, k):
        pipe = (op.RegexMatch("error"),)
        ft, mat, lens = self._strings()
        ref = self._solo(pipe, ft, mat, lens)
        res = self._cluster(pipe, ft, mat, lens, k, partitioner)
        np.testing.assert_array_equal(np.asarray(res.mask),
                                      np.asarray(ref.mask))
        assert res.shipped_bytes == ref.shipped_bytes
        assert res.read_bytes == ref.read_bytes

    def test_crypt_pre_regex(self):
        """Encrypted string rows: partition keystream is byte-addressed by
        original row offsets (row id x width + column)."""
        key, nonce = (5, 7), 9
        pipe = (op.Crypt(key=key, nonce=nonce, when="pre"),
                op.RegexMatch("error"))
        ft, mat, lens = self._strings()
        enc = np.asarray(kref.ctr_crypt(
            jnp.asarray(mat.reshape(-1).astype(np.uint32)),
            jnp.asarray(key, jnp.uint32), nonce)
        ).astype(np.uint8).reshape(mat.shape)
        ref = self._solo(pipe, ft, enc, lens)
        assert int(np.asarray(ref.mask).sum()) > 0    # decrypt really works
        for k in (2, 3):
            res = self._cluster(pipe, ft, enc, lens, k, "range")
            np.testing.assert_array_equal(np.asarray(res.mask),
                                          np.asarray(ref.mask))


class TestSchedulerComposition:
    """Partition requests keep riding each node's bucket-batched stacks."""

    def test_multi_client_one_dispatch_per_node(self, data):
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        cl = FarCluster(2)
        clients = []
        for c in range(3):
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema(f"t{c}"))
            cl.table_write(cqp, ct, words)
            clients.append((cqp, ct))
        ref = solo_run(pipe, words)
        pends = [cl.submit_request(cqp, ct, pipe) for cqp, ct in clients]
        before = [node.dispatches for node in cl.nodes]
        cl.flush()
        # 3 clients x 2 nodes: ONE stacked executable per node, not 3
        assert [node.dispatches for node in cl.nodes] == [b + 1
                                                          for b in before]
        for pend in pends:
            assert_rows_identical(pend.wait().finalize(), ref)

    def test_per_node_accounting_aggregates(self, data):
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        res, cl, cqp, ct = cluster_run(pipe, words, 3, "range")
        # aggregate counters equal solo; per-node shares partition them
        assert cqp.bytes_read_pool == ref.read_bytes
        assert cqp.bytes_shipped == ref.shipped_bytes
        per_node_read = [qp.bytes_read_pool for qp in cqp.qps]
        assert sum(per_node_read) == ref.read_bytes
        assert all(r > 0 for r in per_node_read)      # every node did work
        assert cl.stats.bytes_read == ref.read_bytes
        assert cl.stats.bytes_shipped == ref.shipped_bytes
        assert cqp.requests == 1                      # one cluster verb

    def test_sequential_flush_matches_parallel(self, data):
        pipe = (op.Select((op.Predicate("c3", ">", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        for parallel in (False, True):
            cl = FarCluster(3, parallel=parallel)
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema())
            cl.table_write(cqp, ct, words)
            res = cl.farview_request(cqp, ct, pipe).finalize()
            assert_rows_identical(res, ref)

    def test_replicated_table_serves_solo_shaped(self, data):
        """A verb against a replicated table is served whole from node 0
        and returns the solo response directly (no merge rebuild)."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),
                op.Crypt(key=(3, 9), nonce=4, when="post"))
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl = FarCluster(3)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema(), replicate=True)
        cl.table_write(cqp, ct, words)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        assert_rows_identical(res, ref)
        assert cl.nodes[0].dispatches == 1      # node 0 serves...
        assert cl.nodes[1].dispatches == 0      # ...the others idle

    def test_table_read_roundtrip(self, data):
        words = schema().encode(data)
        for partitioner in PARTITIONERS:
            cl = FarCluster(3, partitioner=partitioner)
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema(),
                                    keys=data["c0"]
                                    if partitioner != "range" else None)
            cl.table_write(cqp, ct, words)
            got = np.asarray(cl.table_read(cqp, ct))
            np.testing.assert_array_equal(got, words.astype(np.float32))


class TestMergeEdgeCases:
    """merge_group_partials on degenerate partials."""

    def _group_partial(self, words, pipe, row_ids=None):
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        ft = FTable("t", COLS, n_rows=words.shape[0])
        alloc_table_mem(qp, ft)
        table_write(qp, ft, words)
        return farview_request(qp, ft, pipe, row_ids=row_ids).finalize()

    def test_empty_partials_list(self):
        res = merge_group_partials(schema(), (), [])
        assert res.kind == "rows" and res.count == 0

    def test_empty_partials_list_padded(self):
        res = merge_group_partials(schema(), (), [], n_rows=16)
        assert np.asarray(res.rows).shape == (16, len(COLS))
        assert not np.asarray(res.rows).any()

    def test_empty_partials_keep_pipeline_kind(self):
        """A zero-row table's merged result has the pipeline's kind and
        response width, not a hardcoded rows/schema shape."""
        res = merge_group_partials(schema(), (op.RegexMatch("x"),), [],
                                   n_rows=0)
        assert res.kind == "mask" and np.asarray(res.mask).shape == (0,)
        res = merge_group_partials(schema(),
                                   (op.GroupBy("c0", ("c1",)),), [])
        assert res.kind == "groups" and res.groups == {}
        res = merge_group_partials(schema(),
                                   (op.SmartAddress(("c1",)),), [])
        assert np.asarray(res.rows).shape == (0, 1)       # narrowed
        jpipe = (op.JoinSmall(probe_key="c0", build_table="b",
                              build_key="k", build_cols=("v", "w")),)
        res = merge_group_partials(schema(), jpipe, [])
        assert np.asarray(res.rows).shape == (0, len(COLS) + 3)

    def test_zero_row_cluster_table(self):
        """End-to-end: an empty table scatters to nobody and still merges
        to the right kind."""
        cl = FarCluster(2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("empty", COLS, n_rows=0))
        assert all(p is None for p in ct.parts)
        res = cl.farview_request(
            cqp, ct, (op.GroupBy("c0", ("c1",)),)).finalize()
        assert res.kind == "groups" and res.groups == {}

    def test_empty_partition_skipped(self, data):
        """A cluster bigger than the table: some nodes own zero rows and
        are never dispatched to; the merge still matches solo."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        rng = np.random.default_rng(7)
        small = {"c0": np.arange(3, dtype=np.int32)}
        for i in range(1, 8):
            small[f"c{i}"] = rng.integers(-5, 5, 3).astype(np.float32)
        ft = FTable("tiny", COLS, n_rows=3)
        words = ft.encode(small)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        solo_ft = FTable("tiny", COLS, n_rows=3)
        alloc_table_mem(qp, solo_ft)
        table_write(qp, solo_ft, words)
        ref = farview_request(qp, solo_ft, pipe).finalize()
        cl = FarCluster(5)      # 5 nodes, 3 rows: >= 2 empty partitions
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("tiny", COLS, n_rows=3))
        assert sum(1 for p in ct.parts if p is None) >= 2
        cl.table_write(cqp, ct, words)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        assert_rows_identical(res, ref)

    def test_single_group(self):
        """Every row in one group: one claimed bucket, rest sentinel."""
        rng = np.random.default_rng(8)
        d = {"c0": np.full(64, 5, np.int32)}
        for i in range(1, 8):
            d[f"c{i}"] = rng.integers(0, 9, 64).astype(np.float32)
        ft = FTable("t", COLS, n_rows=64)
        words = ft.encode(d)
        pipe = (op.GroupBy("c0", ("c1",), n_buckets=64),)
        merged = merge_group_partials(
            ft, pipe, [self._group_partial(words, pipe)]).groups
        assert list(merged) == [5]
        cnt, s, mn, mx = merged[5]
        assert cnt == 64
        np.testing.assert_array_equal(np.asarray(s).reshape(()),
                                      d["c1"].sum())

    def test_all_rows_filtered(self):
        """Selection drops everything: group partials carry only dropped
        keys; the merge is empty (drop_key never leaks)."""
        rng = np.random.default_rng(9)
        d = {"c0": rng.integers(0, 5, 64).astype(np.int32)}
        for i in range(1, 8):
            d[f"c{i}"] = rng.integers(0, 9, 64).astype(np.float32)
        ft = FTable("t", COLS, n_rows=64)
        words = ft.encode(d)
        pipe = (op.Select((op.Predicate("c1", ">", 1e9),)),
                op.GroupBy("c0", ("c1",), n_buckets=64))
        merged = merge_group_partials(
            ft, pipe, [self._group_partial(words, pipe)]).groups
        assert merged == {}
        # rows kind, all filtered, via the cluster merge path
        spipe = (op.Select((op.Predicate("c1", ">", 1e9),)),)
        parts = [self._group_partial(words, spipe,
                                     row_ids=np.arange(64, dtype=np.int32))]
        res = merge_group_partials(ft, spipe, parts, n_rows=64,
                                   part_rows=[np.arange(64)])
        assert res.count == 0
        assert not np.asarray(res.rows).any()

    def test_rows_merge_reorders_by_sel_ids(self):
        """Out-of-order partials (hash partitions) splice back exactly."""
        rows_a = jnp.asarray(np.asarray([[3.0, 3.0], [9.0, 9.0]]))
        rows_b = jnp.asarray(np.asarray([[1.0, 1.0], [7.0, 7.0]]))
        pa = PipelineResult("rows", rows=rows_a, count=2,
                            sel_ids=np.asarray([3, 9]), shipped_bytes=16,
                            read_bytes=32)
        pb = PipelineResult("rows", rows=rows_b, count=2,
                            sel_ids=np.asarray([1, 7]), shipped_bytes=16,
                            read_bytes=32)
        ft = FTable("t", (Column("a"), Column("b")), n_rows=12)
        res = merge_group_partials(ft, (), [pa, pb], n_rows=12)
        out = np.asarray(res.rows)
        np.testing.assert_array_equal(out[:4, 0], [1.0, 3.0, 7.0, 9.0])
        assert res.count == 4 and not out[4:].any()
        assert res.shipped_bytes == 32 and res.read_bytes == 64


class TestCloseConnection:
    def test_cluster_close_cancels_partition_requests(self, data):
        """Closing a ClusterQP cancels its queued partials on EVERY node;
        other tenants' requests still dispatch."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl = FarCluster(2)
        doomed_qp = cl.open_connection()
        alive_qp = cl.open_connection()
        doomed_ct = cl.alloc_table_mem(doomed_qp, schema("d"))
        alive_ct = cl.alloc_table_mem(alive_qp, schema("a"))
        cl.table_write(doomed_qp, doomed_ct, words)
        cl.table_write(alive_qp, alive_ct, words)
        doomed = cl.submit_request(doomed_qp, doomed_ct, pipe)
        alive = cl.submit_request(alive_qp, alive_ct, pipe)
        cl.close_connection(doomed_qp)
        with pytest.raises(FarviewError, match="closed"):
            doomed.wait()
        assert_rows_identical(alive.wait().finalize(), ref)
        # further verbs on the closed connection are refused outright
        with pytest.raises(FarviewError, match="closed"):
            cl.submit_request(doomed_qp, doomed_ct, pipe)

    def test_close_cancels_only_own_requests(self):
        """Node-level: two queued requests from one QPair both cancel; a
        third tenant's queued request survives and the freed region's new
        tenant sees no ghost traffic."""
        rng = np.random.default_rng(11)
        node = FViewNode(64 * 2**20, n_regions=3)
        qp1 = open_connection(node)
        qp2 = open_connection(node)
        d = {f"c{i}": rng.normal(size=128).astype(np.float32)
             for i in range(8)}
        d["c0"] = rng.integers(0, 9, 128).astype(np.int32)
        fts = []
        for name, qp in (("x", qp1), ("y", qp1), ("z", qp2)):
            ft = FTable(name, COLS, n_rows=128)
            alloc_table_mem(qp, ft)
            table_write(qp, ft, ft.encode(d))
            fts.append(ft)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        doomed1 = submit_request(qp1, fts[0], pipe)
        doomed2 = submit_request(qp1, fts[1], pipe)
        alive = submit_request(qp2, fts[2], pipe)
        close_connection(qp1)
        for pend in (doomed1, doomed2):
            with pytest.raises(FarviewError, match="closed"):
                pend.wait()
        assert alive.wait().count == int((d["c1"] < 0.0).sum())
        qp3 = open_connection(node)
        assert qp3.region == qp1.region
        assert qp3.requests == 0

    def test_settle_after_close_is_clean(self):
        """settle() after a close with queued requests neither raises nor
        dispatches the cancelled work."""
        rng = np.random.default_rng(12)
        node = FViewNode(64 * 2**20, n_regions=1)
        qp = open_connection(node)
        ft = FTable("t", COLS, n_rows=64)
        alloc_table_mem(qp, ft)
        d = {f"c{i}": rng.normal(size=64).astype(np.float32)
             for i in range(8)}
        d["c0"] = np.zeros(64, np.int32)
        table_write(qp, ft, ft.encode(d))
        pend = submit_request(qp, ft, (op.Select(
            (op.Predicate("c1", "<", 0.0),)),))
        before = node.dispatches
        close_connection(qp)
        node.settle()
        assert node.dispatches == before
        with pytest.raises(FarviewError, match="closed"):
            pend.wait()


class TestPartitioners:
    def test_partition_rows_cover_exactly(self):
        rng = np.random.default_rng(13)
        keys = rng.integers(0, 7, 101)
        for kind in PARTITIONERS:
            parts = partition_rows(101, 4, kind,
                                   keys=keys if kind != "range" else None)
            assert len(parts) == 4
            got = np.sort(np.concatenate(parts))
            np.testing.assert_array_equal(got, np.arange(101))

    def test_hash_colocates_equal_keys(self):
        rng = np.random.default_rng(14)
        keys = rng.integers(0, 9, 200)
        parts = partition_rows(200, 3, "hash", keys=keys)
        owner = np.empty(200, np.int64)
        for i, p in enumerate(parts):
            owner[p] = i
        for key in np.unique(keys):
            assert len(np.unique(owner[keys == key])) == 1

    def test_skew_balances_heavy_hitter(self):
        """90% of rows share one key: skew-aware placement bounds the
        hottest node at the heavy group, never heavy + more."""
        keys = np.asarray([0] * 90 + list(range(1, 11)))
        parts = partition_rows(100, 3, "skew", keys=keys)
        sizes = sorted(len(p) for p in parts)
        assert max(sizes) == 90          # heavy key alone on one node
        assert sizes[0] + sizes[1] == 10  # the rest spread over the others
        owner = np.empty(100, np.int64)
        for i, p in enumerate(parts):
            owner[p] = i
        for key in np.unique(keys):      # still co-located per key
            assert len(np.unique(owner[keys == key])) == 1

    def test_unknown_partitioner_raises(self):
        with pytest.raises(ValueError, match="unknown partitioner"):
            partition_rows(10, 2, "rendezvous")

    def test_range_with_keys_raises(self):
        """Silently dropping the co-location keys would be a footgun."""
        with pytest.raises(ValueError, match="ignores them"):
            partition_rows(10, 2, "range", keys=np.arange(10))

    def test_response_width_matches_actual_packing(self, data):
        """The compiled plan's response_width (used to shape empty merged
        results) must track what _body actually packs."""
        from repro.core.pipeline import compile_pipeline
        rng = np.random.default_rng(21)
        bft = FTable("bw", (Column("k", "i32"), Column("v"), Column("w")),
                     n_rows=8)
        bwords = bft.encode({"k": np.arange(8, dtype=np.int32),
                             "v": rng.random(8).astype(np.float32),
                             "w": rng.random(8).astype(np.float32)})
        pipes = [
            (op.Select((op.Predicate("c1", "<", 0.0),)),),
            (op.Project(("c1", "c3")),),
            (op.SmartAddress(("c2", "c5")),),
            (op.JoinSmall(probe_key="c0", build_table="bw",
                          build_key="k", build_cols=("v", "w")),),
        ]
        words = schema().encode(data)
        for pipe in pipes:
            build = (bft, bwords) if any(
                isinstance(o, op.JoinSmall) for o in pipe) else None
            res = solo_run(pipe, words, build=build)
            assert (np.asarray(res.rows).shape[1]
                    == compile_pipeline(schema(), pipe).response_width), pipe

    def test_failed_alloc_rolls_back_earlier_nodes(self):
        """A mid-scatter pool-exhaustion frees the partitions already
        allocated on earlier nodes (no orphaned pages)."""
        cl = FarCluster(2, 8 * 2**20)       # 4 x 2 MiB pages per node
        cqp = cl.open_connection()
        # node 1 nearly full (3 of 4 pages): its half of `big` won't fit
        cl.nodes[1].pool.alloc_table(
            FTable("solo-hog", COLS, n_rows=163840))        # 5 MiB
        free_before = [node.pool.free_pages for node in cl.nodes]
        big = FTable("big", COLS, n_rows=300000)    # 4.6 MiB per partition
        with pytest.raises(MemoryError):
            cl.alloc_table_mem(cqp, big)
        assert [node.pool.free_pages for node in cl.nodes] == free_before

    def test_alloc_rejects_f32_inexact_row_ids(self):
        """Row ids ride the packing as f32: tables at/above 2^24 rows
        would silently scramble the merge order, so alloc refuses them."""
        cl = FarCluster(2)
        cqp = cl.open_connection()
        big = FTable("big", COLS, n_rows=1 << 24)
        with pytest.raises(ValueError, match="f32-exact"):
            cl.alloc_table_mem(cqp, big)
