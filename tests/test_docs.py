"""Docs-drift guard as a tier-1 test (the same checks CI's lint job runs
via ``tools/check_docs.py``): intra-repo markdown links must resolve, the
documented tier-1 command must match the CI workflow, and the PR 5 docs
suite must exist and be reachable from the README."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_check_docs_passes():
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_docs.py")],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr + proc.stdout


def test_docs_suite_exists():
    for name in ("architecture.md", "cluster.md", "operators.md"):
        assert os.path.exists(os.path.join(ROOT, "docs", name)), name


def test_readme_links_docs_suite():
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        readme = f.read()
    for name in ("docs/architecture.md", "docs/cluster.md",
                 "docs/operators.md"):
        assert name in readme, f"README must link {name}"


@pytest.mark.parametrize("bad", ["docs/no-such-file.md"])
def test_guard_catches_broken_link(tmp_path, bad):
    sys.path.insert(0, os.path.join(ROOT, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    md = tmp_path / "x.md"
    md.write_text(f"see [here]({bad}) and [ok](https://example.com)")
    broken = check_docs.broken_links(str(md), root=str(tmp_path))
    assert len(broken) == 1 and broken[0][0] == bad
