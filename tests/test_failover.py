"""Replication, failure detection and self-healing (PR 6 tentpole).

Contract under test: a FarCluster allocated with `replicas=k` survives
the death of any single node with ZERO wrong bytes —

(a) failover parity: a node killed while requests are IN FLIGHT makes
    the gather reroute that node's partitions to a replica and the
    merged result stays byte-identical to a healthy run, for selection,
    group-aggregate, regex, crypt and co-partitioned join at 2 and 4
    nodes;
(b) health lifecycle: dropped dispatches retry on the SAME node and
    strike it to SUSPECT; `dead_after` consecutive strikes (or one
    NodeDeadError) escalate to DEAD; a success heals SUSPECT back to
    ALIVE but never DEAD; a slow drain heartbeat is a strike;
(c) self-healing: `heal()` promotes replicas to primaries, restores
    k-fold redundancy on the survivors, bumps the table version, and
    the healed cluster answers byte-identically;
(d) redundancy exhausted is LOUD and typed: k=1 death raises
    NodeDeadError; killing every holder of a partition raises
    ReplicaUnavailableError; a cold-storage snapshot is the last
    resort (`snapshot` + `heal(manager=)` round-trips the bytes);
(e) teardown verbs tolerate the dead: `free_table_mem` and
    `close_connection` skip DEAD nodes with a warning instead of
    raising, and close racing an in-flight map flip stays clean.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FarviewError, FViewNode, NodeDeadError,
                               alloc_table_mem, farview_request,
                               merge_group_partials, open_connection,
                               table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column, string_table
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.health import (ALIVE, DEAD, SUSPECT,
                                      DroppedDispatchError, FaultInjector,
                                      HealthMonitor, ReplicaUnavailableError)
from repro.kernels import ref as kref

N = 600
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
KEY, NONCE = (11, 22), 7
NODE_COUNTS = (2, 4)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 8):
        # integer-valued floats: sums are order-insensitive, so
        # byte-identical is meaningful for aggregates too
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return d


def schema(name="t"):
    return FTable(name, COLS, n_rows=N)


def solo_run(pipe, words):
    node = FViewNode(64 * 2**20)
    qp = open_connection(node)
    ft = schema()
    alloc_table_mem(qp, ft)
    table_write(qp, ft, words)
    return farview_request(qp, ft, pipe).finalize()


def replicated_cluster(words, k, *, partitioner="range", keys=None,
                       replicas=2):
    cl = FarCluster(k, partitioner=partitioner, replicas=replicas)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, schema(), keys=keys)
    cl.table_write(cqp, ct, words)
    return cl, cqp, ct


def assert_rows_identical(res, ref):
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes


class TestFailoverParity:
    """Kill a node while requests are in flight; results stay
    byte-identical to a healthy run."""

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_selection_mid_stream_kill(self, data, k):
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),
                           op.Predicate("c2", ">", -20.0))),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl, cqp, ct = replicated_cluster(words, k)
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(k - 1)           # dies AFTER submit, BEFORE drain
        res = pend.wait().finalize()
        assert_rows_identical(res, ref)
        assert cl.health.state(k - 1) == DEAD
        assert ct.heat.failovers >= 1

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_group_aggregate_mid_stream_kill(self, data, k):
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_run(pipe, words)]).groups
        cl, cqp, ct = replicated_cluster(words, k, partitioner="hash",
                                         keys=data["c0"])
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(0)
        got = pend.wait().finalize().groups
        assert set(got) == set(ref)
        for key in ref:
            for a, b in zip(ref[key], got[key]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_crypt_post_mid_stream_kill(self, data, k):
        """Rerouted partitions keep the keystream addressed by ORIGINAL
        row offsets, so the spliced ciphertext is exact."""
        pipe = (op.Select((op.Predicate("c2", ">", 0.0),)),
                op.Crypt(key=KEY, nonce=NONCE, when="post"))
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl, cqp, ct = replicated_cluster(words, k, partitioner="hash",
                                         keys=data["c0"])
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(k - 1)
        assert_rows_identical(pend.wait().finalize(), ref)

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_crypt_pre_mid_stream_kill(self, data, k):
        """Encrypted-at-rest: the replica holds the same ciphertext bytes
        as the primary, so the rerouted decrypt still lines up."""
        pipe = (op.Crypt(key=KEY, nonce=NONCE, when="pre"),
                op.Select((op.Predicate("c1", "<", 0.0),)))
        flat = jnp.asarray(schema().encode(data).reshape(-1))
        enc = np.asarray(kref.ctr_crypt(
            flat.view(jnp.uint32), jnp.asarray(KEY, jnp.uint32), NONCE)
        ).view(np.float32).reshape(N, len(COLS))
        ref = solo_run(pipe, enc)
        assert ref.count > 0
        cl, cqp, ct = replicated_cluster(enc, k)
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(0)
        assert_rows_identical(pend.wait().finalize(), ref)

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_regex_mid_stream_kill(self, k):
        strs = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
                b"errr", b"the error is late"]
        rng = np.random.default_rng(5)
        ft, mat, lens = string_table(
            "s", [strs[j] for j in rng.integers(0, len(strs), 300)], 24)
        pipe = (op.RegexMatch("error"),)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        solo_ft = FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                         str_width=ft.str_width)
        alloc_table_mem(qp, solo_ft)
        ref = farview_request(qp, solo_ft, pipe,
                              strings=mat, lengths=lens).finalize()
        cl = FarCluster(k, replicas=2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(
            cqp, FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                        str_width=ft.str_width))
        pend = cl.submit_request(cqp, ct, pipe, strings=mat, lengths=lens)
        cl.fault.kill(k - 1)
        res = pend.wait().finalize()
        np.testing.assert_array_equal(np.asarray(res.mask),
                                      np.asarray(ref.mask))

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_copartitioned_join_mid_stream_kill(self, data, k):
        """The cyclic replica rule keeps probe and build replicas
        CO-LOCATED, so the rerouted node still answers the join from a
        local build shard (via its `@p{i}` alias)."""
        rng = np.random.default_rng(3)
        bft = FTable("cust", (Column("k", "i32"), Column("v")), n_rows=40)
        bd = {"k": rng.permutation(64)[:40].astype(np.int32),
              "v": rng.integers(0, 99, 40).astype(np.float32)}
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        jdata = dict(data)
        jdata["c0"] = rng.integers(0, 64, N).astype(np.int32)
        words = schema().encode(jdata)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        alloc_table_mem(qp, b)
        table_write(qp, b, b.encode(bd))
        ft = schema()
        alloc_table_mem(qp, ft)
        table_write(qp, ft, words)
        ref = farview_request(qp, ft, pipe).finalize()

        cl = FarCluster(k, replicas=2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema(), partitioner="hash",
                                keys=jdata["c0"])
        cl.table_write(cqp, ct, words)
        cb = cl.alloc_table_mem(
            cqp, FTable(bft.name, bft.columns, n_rows=bft.n_rows),
            co_partition=ct, keys=bd["k"])
        cl.table_write(cqp, cb, bft.encode(bd))
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(k - 1)
        assert_rows_identical(pend.wait().finalize(), ref)
        # and again after healing, from the promoted primaries
        cl.heal(cqp)
        assert_rows_identical(
            cl.farview_request(cqp, ct, pipe).finalize(), ref)

    def test_kill_before_submit_routes_around(self, data):
        """A node already DEAD at submit time is never dispatched to."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(1)
        cl.health.mark_dead(1)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        assert_rows_identical(res, ref)
        assert cl.nodes[1].dispatches == 0

    def test_table_read_fails_over(self, data):
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(2)
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words.astype(np.float32))
        assert cl.health.state(2) == DEAD


class TestHealthLifecycle:
    def test_dropped_dispatch_retries_same_node(self, data):
        """A transient drop retries on the SAME node (no failover), the
        result is exact, and the node is left SUSPECT, not DEAD."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl, cqp, ct = replicated_cluster(words, 2)
        cl.fault.drop_dispatches(1, 1)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        assert_rows_identical(res, ref)
        assert cl.health.state(1) == SUSPECT
        assert ct.heat.failovers == 0          # same-node retry, no reroute
        # the next healthy round heals it back to ALIVE
        cl.farview_request(cqp, ct, pipe)
        assert cl.health.state(1) == ALIVE

    def test_strikes_escalate_to_dead(self):
        mon = HealthMonitor(2, dead_after=3)
        err = FarviewError("transient")
        assert mon.record_failure(1, err) == SUSPECT
        assert mon.record_failure(1, err) == SUSPECT
        assert mon.record_failure(1, err) == DEAD
        assert mon.dead_nodes() == [1]
        # success does NOT resurrect a dead node; revive() does
        mon.record_success(1)
        assert mon.state(1) == DEAD
        mon.revive(1)
        assert mon.state(1) == ALIVE and mon.alive_nodes() == [0, 1]

    def test_node_dead_error_is_conclusive(self):
        mon = HealthMonitor(3)
        assert mon.record_failure(0, NodeDeadError(0)) == DEAD
        assert mon.summary() == {0: DEAD, 1: ALIVE, 2: ALIVE}

    def test_slow_heartbeat_is_a_strike(self):
        mon = HealthMonitor(1, dead_after=2, slow_after_s=0.5)
        mon.heartbeat(0, 0.1)
        assert mon.state(0) == ALIVE
        mon.heartbeat(0, 1.0)
        assert mon.state(0) == SUSPECT
        mon.heartbeat(0, 2.0)
        assert mon.state(0) == DEAD
        assert mon.nodes[0].heartbeats == 3

    def test_slow_node_escalates_via_flush(self, data):
        """An injected slow fault makes the drain latency trip the
        heartbeat threshold — detection with no separate prober."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        cl = FarCluster(2, replicas=2, slow_after_s=0.05, dead_after=2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        cl.fault.slow(1, 0.2)
        cl.farview_request(cqp, ct, pipe)
        assert cl.health.state(1) in (SUSPECT, DEAD)

    def test_user_error_is_not_a_strike(self, data):
        """A bad pipeline is the USER's failure; the node that faithfully
        reported it must stay ALIVE."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 2)
        with pytest.raises(KeyError, match="nope"):
            cl.farview_request(
                cqp, ct, (op.Select((op.Predicate("nope", "<", 0.0),)),))
        assert all(cl.health.state(i) == ALIVE for i in range(2))

    def test_flush_error_carries_node_identity(self, data):
        """Satellite (a): the per-node exception surfaced by flush names
        the node that raised it."""
        words = schema().encode(data)
        cl = FarCluster(2)          # k=1: nothing to fail over to
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        pend = cl.submit_request(
            cqp, ct, (op.Select((op.Predicate("c1", "<", 0.0),)),))
        cl.fault.kill(1)
        with pytest.raises(NodeDeadError) as ei:
            pend.wait()
        assert ei.value.node_id == 1
        assert getattr(ei.value, "fv_node_id", None) == 1


class TestSelfHealing:
    def test_heal_promotes_and_rereplicates(self, data):
        words = schema().encode(data)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        ref = solo_run(pipe, words)
        cl, cqp, ct = replicated_cluster(words, 3)
        v0 = ct.version
        cl.fault.kill(1)
        cl.farview_request(cqp, ct, pipe)            # detect via failover
        report = cl.heal(cqp)
        assert report["dead_nodes"] == [1]
        assert ("t", 1, 2) in report["promoted"]     # cyclic successor
        assert ct.version == v0 + 1
        assert ct.home[1] == 2 and ct.parts[1] is not None
        # full redundancy again: every partition has k=2 alive copies
        for i in range(3):
            holders = {ct.home[i]} | set(ct.replicas[i])
            assert len(holders) == 2
            assert all(cl.health.is_alive(j) for j in holders)
        assert not report["under_replicated"]
        # healed cluster answers byte-identically, without touching node 1
        d1 = cl.nodes[1].dispatches
        assert_rows_identical(
            cl.farview_request(cqp, ct, pipe).finalize(), ref)
        assert cl.nodes[1].dispatches == d1
        # and survives ANOTHER death (the re-replicated copies are real)
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(2)
        assert_rows_identical(pend.wait().finalize(), ref)

    def test_heal_two_node_cluster_under_replicates(self, data):
        """k=2 replicas on 2 nodes, one dies: heal promotes but CANNOT
        restore redundancy — it must say so, not pretend."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 2)
        cl.fault.kill(0)
        cl.health.mark_dead(0)
        with pytest.warns(UserWarning, match="below 2 copies"):
            report = cl.heal(cqp)
        assert report["under_replicated"]
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        assert_rows_identical(
            cl.farview_request(cqp, ct, pipe).finalize(),
            solo_run(pipe, words))

    def test_heal_is_idempotent(self, data):
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(1)
        cl.health.mark_dead(1)
        cl.heal(cqp)
        v1 = ct.version
        report = cl.heal(cqp)                        # nothing left to do
        assert not report["promoted"] and not report["re_replicated"]
        assert ct.version == v1

    def test_rebalance_refuses_dead_cluster(self, data):
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3, partitioner="hash",
                                         keys=data["c0"])
        cl.fault.kill(2)
        cl.health.mark_dead(2)
        with pytest.raises(FarviewError, match="heal"):
            cl.rebalance(cqp, ct, keys=data["c0"])


class TestRedundancyExhausted:
    def test_k1_death_raises_node_dead(self, data):
        words = schema().encode(data)
        cl = FarCluster(2)                           # replicas=1
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        cl.fault.kill(0)
        with pytest.raises(NodeDeadError):
            cl.farview_request(
                cqp, ct, (op.Select((op.Predicate("c1", "<", 0.0),)),))

    def test_all_copies_dead_raises_replica_unavailable(self, data):
        """k=2 on 2 nodes: kill both holders — typed, loud."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 2)
        cl.fault.kill(0)
        cl.fault.kill(1)
        with pytest.raises(ReplicaUnavailableError):
            cl.farview_request(
                cqp, ct, (op.Select((op.Predicate("c1", "<", 0.0),)),))

    def test_heal_without_manager_refuses_lost_partition(self, data):
        words = schema().encode(data)
        cl = FarCluster(3)                           # k=1: death = loss
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        cl.fault.kill(0)
        cl.health.mark_dead(0)
        with pytest.raises(ReplicaUnavailableError, match="manager"):
            cl.heal(cqp)

    def test_snapshot_restore_roundtrip(self, data, tmp_path):
        """The last resort: k=1, node dies, heal(manager=) re-materializes
        the lost partition from the snapshot, byte-for-byte."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl = FarCluster(3)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        mgr = CheckpointManager(str(tmp_path / "ckpt"))
        step = cl.snapshot(cqp, mgr)
        assert mgr.latest_step() == step
        cl.fault.kill(0)
        cl.health.mark_dead(0)
        cl.fault.revive(0)      # pages are gone either way (fresh host)
        report = cl.heal(cqp, manager=mgr)
        assert ("t", (0,)) in report["restored"]
        assert ct.home[0] != 0 and ct.parts[0] is not None
        assert_rows_identical(
            cl.farview_request(cqp, ct, pipe).finalize(), ref)
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words.astype(np.float32))


class TestDeadTolerantTeardown:
    def test_free_table_mem_skips_dead(self, data):
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(1)
        cl.health.mark_dead(1)
        with pytest.warns(UserWarning, match="dead"):
            cl.free_table_mem(cqp, ct)
        assert ct.name not in cl.catalog
        # survivors' pages really freed: a same-size realloc fits
        ct2 = cl.alloc_table_mem(cqp, schema("t2"))
        cl.table_write(cqp, ct2, words)

    def test_close_connection_skips_dead(self, data):
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(2)
        cl.health.mark_dead(2)
        with pytest.warns(UserWarning, match="dead"):
            cl.close_connection(cqp)
        with pytest.raises(FarviewError, match="closed"):
            cl.submit_request(
                cqp, ct, (op.Select((op.Predicate("c1", "<", 0.0),)),))

    def test_close_racing_map_flip(self, data):
        """Satellite (b): a connection closed between submit and settle of
        a heal (map flip) neither deadlocks nor double-frees; the OTHER
        tenant's table flips and keeps answering."""
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        cl = FarCluster(3, replicas=2)
        doomed_qp = cl.open_connection()
        alive_qp = cl.open_connection()
        doomed_ct = cl.alloc_table_mem(doomed_qp, schema("d"))
        alive_ct = cl.alloc_table_mem(alive_qp, schema("a"))
        cl.table_write(doomed_qp, doomed_ct, words)
        cl.table_write(alive_qp, alive_ct, words)
        doomed = cl.submit_request(doomed_qp, doomed_ct, pipe)
        cl.fault.kill(1)
        cl.health.mark_dead(1)
        cl.close_connection(doomed_qp)          # races the upcoming flip
        cl.heal(alive_qp)                       # flips BOTH tables' maps
        with pytest.raises(FarviewError, match="closed"):
            doomed.wait()
        assert alive_ct.home[1] != 1
        assert_rows_identical(
            cl.farview_request(alive_qp, alive_ct, pipe).finalize(), ref)

    def test_writes_skip_dead_copies(self, data):
        """table_write lands on every ALIVE copy and warns about the dead
        one; reads after heal still see the new bytes."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        cl.fault.kill(1)
        cl.health.mark_dead(1)
        words2 = words + 1.0
        with pytest.warns(UserWarning, match="dead"):
            cl.table_write(cqp, ct, words2)
        cl.heal(cqp)
        got = np.asarray(cl.table_read(cqp, ct))
        np.testing.assert_array_equal(got, words2.astype(np.float32))


class TestReplicaPlacement:
    def test_cyclic_layout_and_aliases(self, data):
        """Copy r of partition i lands on (i + r) % n, and every copy is
        cataloged under the `name@p{i}` alias on its holder."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        assert ct.k_replicas == 2
        for i in range(3):
            assert ct.home[i] == i
            assert list(ct.replicas[i]) == [(i + 1) % 3]
            assert f"t@p{i}" in cl.nodes[i].tables
            assert f"t@p{i}" in cl.nodes[(i + 1) % 3].tables

    def test_replica_bytes_accounted(self, data):
        """Write amplification is visible: replica bytes are tracked per
        node, separately from primary traffic."""
        words = schema().encode(data)
        cl, cqp, ct = replicated_cluster(words, 3)
        assert ct.heat.replica_bytes_written is not None
        assert int(ct.heat.replica_bytes_written.sum()) > 0

    def test_replicas_validate_bounds(self):
        with pytest.raises(ValueError):
            FarCluster(2, replicas=3)
        with pytest.raises(ValueError):
            FarCluster(2, replicas=0)

    def test_default_k1_layout_unchanged(self, data):
        """replicas=1 (the default) keeps the PR3-PR5 layout: no replica
        dicts populated, identity homes, plain names resolve."""
        words = schema().encode(data)
        cl = FarCluster(3)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema())
        cl.table_write(cqp, ct, words)
        assert ct.home == [0, 1, 2]
        assert all(not r for r in ct.replicas)
        assert all("t" in cl.nodes[i].tables for i in range(3))

    def test_fault_injector_is_shared_and_scoped(self, data):
        """One injector serves all nodes; reviving clears every fault."""
        cl = FarCluster(2, replicas=2)
        assert all(node.fault is cl.fault for node in cl.nodes)
        cl.fault.kill(0)
        cl.fault.slow(0, 9.0)
        assert cl.fault.is_killed(0)
        cl.fault.revive(0)
        assert not cl.fault.is_killed(0)
        inj = FaultInjector()
        inj.drop_dispatches(0, 2)
        with pytest.raises(DroppedDispatchError):
            inj.check(0, "dispatch")
        with pytest.raises(DroppedDispatchError):
            inj.check(0, "dispatch")
        inj.check(0, "dispatch")               # budget spent: clean
