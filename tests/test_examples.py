"""Example smoke tests: the documented entry points must actually run.

Each example executes as a subprocess the way the README tells users to
run it (``PYTHONPATH=src python examples/<name>.py``), scaled down via
FARVIEW_EXAMPLE_ROWS so the smoke stays cheap. The examples carry their
own correctness asserts (numpy cross-checks), so exit code 0 means the
documented workflow works end-to-end, not just that imports resolve."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(name: str, rows: int = 384) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["FARVIEW_EXAMPLE_ROWS"] = str(rows)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name)],
        capture_output=True, text=True, env=env, timeout=600)


@pytest.mark.parametrize("name,expect", [
    ("quickstart.py", "push-down ships"),
    ("farview_queries.py", "node totals:"),
])
def test_example_runs(name, expect):
    proc = _run_example(name)
    assert proc.returncode == 0, (
        f"{name} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert expect in proc.stdout, proc.stdout
