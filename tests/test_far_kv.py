"""Unit tests for the far-KV library (core/far_kv.py): the disaggregated
KV pool primitives used by the serving stack."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import far_kv
from repro.jax_compat import make_mesh, shard_map
from repro.kernels import ref as kref


def test_partial_attention_matches_oracle(rng):
    b, hq, hkv, d, s = 2, 8, 2, 32, 128
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    lengths = jnp.asarray([100, 37], jnp.int32)
    o, m, l = far_kv.partial_attention(q, k, v, lengths, scale=d ** -0.5)
    full = o / jnp.maximum(l, 1e-30)[..., None]
    ref = kref.full_attention_oracle(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_partial_attention_bf16_cache(rng):
    """The MXU-native path: bf16 K/V, f32 accumulation, no f32 copies."""
    b, hq, hkv, d, s = 2, 4, 4, 32, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k32 = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v32 = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    lengths = jnp.asarray([64, 20], jnp.int32)
    o, m, l = far_kv.partial_attention(
        q, k32.astype(jnp.bfloat16), v32.astype(jnp.bfloat16), lengths,
        scale=d ** -0.5)
    assert o.dtype == jnp.float32          # f32 accumulation preserved
    full = o / jnp.maximum(l, 1e-30)[..., None]
    ref = kref.full_attention_oracle(q, k32, v32, lengths)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def test_shipped_bytes_model_monotonicity():
    """far is constant in S; naive grows linearly; local is smallest wire."""
    kw = dict(batch=8, hq=32, hkv=8, head_dim=128, tp=16)
    far_4k = far_kv.shipped_bytes_per_layer("far", seq_len=4096, **kw)
    far_500k = far_kv.shipped_bytes_per_layer("far", seq_len=524288, **kw)
    assert far_4k == far_500k              # push-down ships O(1) in S
    nai_4k = far_kv.shipped_bytes_per_layer("naive", seq_len=4096, **kw)
    nai_8k = far_kv.shipped_bytes_per_layer("naive", seq_len=8192, **kw)
    assert nai_8k > 1.9 * nai_4k           # fetch grows ~linearly in S
    assert nai_4k > far_4k                  # push-down always cheaper
    loc = far_kv.shipped_bytes_per_layer("local", seq_len=4096, **kw)
    assert loc < far_4k


def test_append_seq_sharded_semantics(rng):
    """append writes exactly the owning shard's row (simulated shards)."""
    # emulate 4 shards with vmap over an explicit axis using shard_map on
    # a 1-device mesh is overkill; test the index math directly
    b, s_loc, hkv, d = 2, 16, 2, 8
    import functools
    mesh = make_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P
    k_cache = jnp.zeros((b, s_loc, hkv, d))
    v_cache = jnp.zeros((b, s_loc, hkv, d))
    k_new = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)

    def run(pos):
        f = shard_map(
            functools.partial(far_kv.append_seq_sharded, axis="model"),
            mesh=mesh, in_specs=(P(), P(), P(), P(), P()),
            out_specs=(P(), P()), check_vma=False)
        return f(k_cache, v_cache, k_new, v_new, jnp.int32(pos))

    k2, v2 = run(5)
    np.testing.assert_allclose(np.asarray(k2[:, 5]), np.asarray(k_new),
                               rtol=1e-6)
    assert float(jnp.sum(jnp.abs(k2))) == pytest.approx(
        float(jnp.sum(jnp.abs(k_new))), rel=1e-5)   # only one row written
    # out-of-range pos writes nothing
    k3, v3 = run(99)
    assert float(jnp.sum(jnp.abs(k3))) == 0.0


def test_merge_partials_named_single_axis(rng):
    """pmax/psum merge on a 1-device axis reduces to plain normalize."""
    b, hq, d = 2, 4, 16
    o = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(b, hq)), jnp.float32)
    l = jnp.abs(jnp.asarray(rng.normal(size=(b, hq)), jnp.float32)) + 0.1
    mesh = make_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P
    out = shard_map(
        lambda o, m, l: far_kv.merge_partials_named(o, m, l, "model"),
        mesh=mesh, in_specs=(P(), P(), P()), out_specs=P(),
        check_vma=False)(o, m, l)
    ref = o / l[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
