"""Hot/cold memory tiering byte-parity suite (PR 10 tentpole).

Contract under test: demoting pages to the compressed cold tier is
INVISIBLE to every verb except in the byte accounting —

(a) solo parity: selection / projection / smart addressing / group /
    distinct / crypt(pre+post) run byte-identical against fully-hot,
    fully-cold, and mixed-tier tables (small pool pages make real
    multi-page tables cheap), with cold dispatches billing the
    compressed physical bytes (`read_bytes` strictly below the raw
    read) and identical `shipped_bytes`;
(b) string extents: a demoted string table promotes on first access and
    regex masks stay exact;
(c) tier mechanics: incompressible pages fall back to raw (counter
    says so, tier bit stays raw), a corrupted cold frame raises typed
    `PageCodecError` on promote instead of restoring wrong bytes,
    access hysteresis promotes after `promote_after` touches, a write
    promotes first, and the capacity multiplier is real;
(d) the scheduler: cold tables in one shape bucket still coalesce into
    ONE stacked dispatch; mixed hot/cold rounds split per tier and both
    halves stay byte-identical;
(e) cluster scale (2 and 4 nodes): the same verbs over fully-cold and
    mixed-tier partition placements match the flat solo reference,
    including a node KILLED MID-STREAM whose cold partition fails over
    to a (equally cold) replica, and `demote_cold` only sweeps tables
    the heat ledgers call idle.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FViewNode, PageCodecError, alloc_table_mem,
                               farview_request, merge_group_partials,
                               open_connection, submit_request, table_read,
                               table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column, string_table
from repro.kernels import ref as kref

PAGE = 4096                      # small pool pages: 5-page tables at N=600
N = 600
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
KEY, NONCE = (11, 22), 7
NODE_COUNTS = (2, 4)
MIXED = [0, 2, 4]                # pages demoted in the mixed-tier layout


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 8):
        # integer-valued floats: sums are order-insensitive, so
        # byte-identical is meaningful for aggregates too
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return d


def schema(name="t"):
    return FTable(name, COLS, n_rows=N)


def tiered_node(**kw):
    return FViewNode(2 * 2**20, page_bytes=PAGE, **kw)


def loaded(node, words, name="t"):
    qp = open_connection(node)
    ft = schema(name)
    alloc_table_mem(qp, ft)
    table_write(qp, ft, words)
    return qp, ft


def solo_ref(pipe, words):
    """Flat-DRAM reference: default pages, nothing demoted."""
    node = FViewNode(64 * 2**20)
    qp, ft = loaded(node, words)
    return farview_request(qp, ft, pipe).finalize()


def assert_rows_identical(res, ref):
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes


VERBS = {
    "selection": (op.Select((op.Predicate("c1", "<", 0.0),
                             op.Predicate("c2", ">", -20.0))),),
    "projection": (op.Project(("c2", "c5")),),
    "smart": (op.SmartAddress(("c3",)),),
    "crypt_post": (op.Select((op.Predicate("c2", ">", 0.0),)),
                   op.Crypt(key=KEY, nonce=NONCE, when="post")),
}
GROUPED = {
    "group": (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),),
    "distinct": (op.Distinct(("c0",), n_buckets=128),),
}


class TestSoloTierParity:
    @pytest.mark.parametrize("verb", sorted(VERBS))
    @pytest.mark.parametrize("tier", ["cold", "mixed"])
    def test_rows_verbs_byte_identical(self, data, verb, tier):
        pipe = VERBS[verb]
        words = schema().encode(data)
        ref = solo_ref(pipe, words)
        node = tiered_node(promote_after=99)    # no promotion mid-test
        qp, ft = loaded(node, words)
        hot = farview_request(qp, ft, pipe).finalize()
        assert_rows_identical(hot, ref)
        n = node.pool.demote_table(
            ft, page_idx=MIXED if tier == "mixed" else None)
        assert n == (len(MIXED) if tier == "mixed" else len(ft.pages))
        res = farview_request(qp, ft, pipe).finalize()
        assert_rows_identical(res, ref)
        # honest accounting: physical (compressed) bytes billed, and the
        # tiered dispatch bills exactly what the descriptors say it read
        assert res.read_bytes < hot.read_bytes
        assert node.pool.is_tiered(ft)

    @pytest.mark.parametrize("verb", sorted(GROUPED))
    @pytest.mark.parametrize("tier", ["cold", "mixed"])
    def test_grouped_verbs_byte_identical(self, data, verb, tier):
        pipe = GROUPED[verb]
        words = schema().encode(data)
        ref = merge_group_partials(
            schema(), pipe if verb == "group" else (),
            [solo_ref(pipe, words)]).groups
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, words)
        node.pool.demote_table(
            ft, page_idx=MIXED if tier == "mixed" else None)
        res = farview_request(qp, ft, pipe).finalize()
        got = merge_group_partials(
            ft, pipe if verb == "group" else (), [res]).groups
        assert set(got) == set(ref)
        for k in ref:
            for a, b in zip(ref[k], got[k]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("tier", ["cold", "mixed"])
    def test_join_small_cold_probe_and_build(self, data, tier):
        """JoinSmall resolves its build table through the pool read path,
        which decodes cold pages host-side — so BOTH sides of the join
        can be demoted and the probe stream still matches exactly."""
        rng = np.random.default_rng(3)
        bcols = (Column("k", "i32"), Column("v"))
        bd = {"k": rng.permutation(64)[:40].astype(np.int32),
              "v": rng.integers(0, 99, 40).astype(np.float32)}
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        jdata = dict(data)
        jdata["c0"] = rng.integers(0, 64, N).astype(np.int32)
        words = schema().encode(jdata)

        def with_build(node):
            qp = open_connection(node)
            b = FTable("cust", bcols, n_rows=40)
            alloc_table_mem(qp, b)
            table_write(qp, b, b.encode(bd))
            return b

        node_ref = FViewNode(64 * 2**20)
        with_build(node_ref)
        qp, ft = loaded(node_ref, words)
        ref = farview_request(qp, ft, pipe).finalize()
        assert ref.count > 0

        node = tiered_node(promote_after=99)
        b = with_build(node)
        qp, ft = loaded(node, words)
        node.pool.demote_table(
            ft, page_idx=MIXED if tier == "mixed" else None)
        node.pool.demote_table(b)               # build side cold too
        assert_rows_identical(farview_request(qp, ft, pipe).finalize(),
                              ref)

    def test_crypt_pre_ciphertext_is_the_raw_fallback(self, data):
        """Encrypted-at-rest pages are pseudo-random: the codec must
        refuse them (None -> raw tier bit) rather than grow the frame,
        and the verb still decrypts byte-identically."""
        pipe = (op.Crypt(key=KEY, nonce=NONCE, when="pre"),
                op.Select((op.Predicate("c1", "<", 0.0),)))
        flat = jnp.asarray(schema().encode(data).reshape(-1))
        enc = np.asarray(kref.ctr_crypt(
            flat.view(jnp.uint32), jnp.asarray(KEY, jnp.uint32), NONCE)
        ).view(np.float32).reshape(N, len(COLS))
        ref = solo_ref(pipe, enc)
        assert ref.count > 0
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, enc)
        before = node.pool.tier_stats["incompressible_pages"]
        # only the zero-padded tail page compresses; every FULL page of
        # ciphertext must be refused and kept raw
        assert node.pool.demote_table(ft) <= 1
        assert node.pool.tier_stats["incompressible_pages"] >= before + 4
        bits = node.pool.tier_bits(ft)
        assert not any(bits[:-1])               # full pages stayed raw
        assert_rows_identical(farview_request(qp, ft, pipe).finalize(), ref)

    def test_table_read_cold_byte_identical(self, data):
        words = schema().encode(data)
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, words)
        node.pool.demote_table(ft)
        np.testing.assert_array_equal(np.asarray(table_read(qp, ft)), words)
        # plain reads bill physical too
        assert qp.bytes_shipped < ft.n_bytes


class TestStringTierParity:
    def test_regex_after_demote_promotes_and_matches(self):
        import re as pyre
        strs = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
                b"errr", b"late error"]
        rng = np.random.default_rng(5)
        picked = [strs[j] for j in rng.integers(0, len(strs), 300)]
        ft, mat, lens = string_table("logs", picked, 48)
        pipe = (op.RegexMatch("error"),)
        node = tiered_node()
        qp = open_connection(node)
        alloc_table_mem(qp, ft)
        assert node.pool.demote_table(ft) > 0       # extent-granular
        assert node.pool.is_tiered(ft)
        res = farview_request(qp, ft, pipe,
                              strings=mat, lengths=lens).finalize()
        # string extents promote on FIRST access (no fused decode path)
        assert not node.pool.is_tiered(ft)
        expect = [bool(pyre.search(b"error", s)) for s in picked]
        assert np.asarray(res.mask).tolist() == expect


class TestTierMechanics:
    def test_corrupt_cold_frame_raises_typed_error(self, data):
        """A flipped bit in a cold frame is a typed failure on promote —
        never wrong bytes quietly restored."""
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, schema().encode(data))
        assert node.pool.demote_table(ft) == len(ft.pages)
        te = node.pool._tier[ft.table_id]
        p = int(np.flatnonzero(te.cold)[0])
        frame, off = int(te.phys[p]), int(te.span[p][0])
        buf = node.pool.buf
        w = buf[frame, off:off + 1].view(jnp.uint32) ^ jnp.uint32(1)
        node.pool.buf = buf.at[frame, off:off + 1].set(w.view(jnp.float32))
        with pytest.raises(PageCodecError):
            node.pool.promote_table(ft)

    def test_access_hysteresis_promotes(self, data):
        pipe = VERBS["selection"]
        node = tiered_node()                    # promote_after=3 default
        qp, ft = loaded(node, schema().encode(data))
        node.pool.demote_table(ft)
        for i in range(2):
            farview_request(qp, ft, pipe).finalize()
            assert node.pool.is_tiered(ft)      # scans don't thrash
        farview_request(qp, ft, pipe).finalize()
        assert not node.pool.is_tiered(ft)      # third touch promotes
        assert node.pool.tier_stats["promoted_pages"] == len(ft.pages)

    def test_write_promotes_first(self, data):
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, schema().encode(data))
        node.pool.demote_table(ft)
        d2 = dict(data)
        d2["c1"] = data["c1"] + 1.0
        words2 = schema().encode(d2)
        table_write(qp, ft, words2)
        assert not node.pool.is_tiered(ft)
        np.testing.assert_array_equal(np.asarray(table_read(qp, ft)),
                                      words2)

    def test_effective_capacity_multiplier(self):
        """Dict-friendly analytics columns (low-cardinality ints) are the
        regime the paper's capacity claim is about: demoting them must
        serve >=1.5 logical bytes per physical byte."""
        cols = tuple(Column(f"k{i}", "i32") for i in range(8))
        ft = FTable("facts", cols, n_rows=4000)
        rng = np.random.default_rng(9)
        d = {c.name: rng.integers(0, 13, 4000).astype(np.int32)
             for c in cols}
        node = tiered_node(promote_after=99)
        qp = open_connection(node)
        alloc_table_mem(qp, ft)
        table_write(qp, ft, ft.encode(d))
        free_before = node.pool.free_pages
        node.pool.demote_table(ft)
        s = node.pool.tier_summary()
        assert s["cold_pages"] == len(ft.pages)
        assert s["effective_capacity"] >= 1.5   # the acceptance bar
        assert node.pool.free_pages > free_before
        np.testing.assert_array_equal(np.asarray(table_read(qp, ft)),
                                      ft.encode(d))

    def test_demote_promote_roundtrip_exact(self, data):
        words = schema().encode(data)
        node = tiered_node(promote_after=99)
        qp, ft = loaded(node, words)
        node.pool.demote_table(ft)
        assert node.pool.promote_table(ft) == len(ft.pages)
        assert not node.pool.is_tiered(ft)
        np.testing.assert_array_equal(np.asarray(table_read(qp, ft)), words)


class TestTieredScheduler:
    def test_cold_tables_coalesce_one_dispatch(self, data):
        """Same-bucket cold tables ride ONE stacked tiered executable,
        each billing its own compressed bytes."""
        node = FViewNode(8 * 2**20, page_bytes=PAGE, n_regions=3,
                         promote_after=99)
        words = schema().encode(data)
        qps, fts = [], []
        for i in range(3):
            qp, ft = loaded(node, words, name=f"c{i}")
            node.pool.demote_table(ft)
            qps.append(qp)
            fts.append(ft)
        pipe = VERBS["selection"]
        ref = solo_ref(pipe, words)
        pends = [submit_request(qp, ft, pipe) for qp, ft in zip(qps, fts)]
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1
        for pend, ft in zip(pends, fts):
            res = pend.wait()
            assert_rows_identical(res, ref)
            assert res.read_bytes == node.pool.tier_read_bytes(ft)
            assert res.read_bytes < ft.n_bytes

    def test_mixed_tier_round_splits_per_tier(self, data):
        """Hot and cold tables in one bucket run as TWO dispatches (the
        tiered executable takes descriptor operands), both exact."""
        node = FViewNode(8 * 2**20, page_bytes=PAGE, n_regions=2,
                         promote_after=99)
        words = schema().encode(data)
        qp_h, ft_h = loaded(node, words, name="hot")
        qp_c, ft_c = loaded(node, words, name="cold")
        node.pool.demote_table(ft_c)
        pipe = VERBS["selection"]
        ref = solo_ref(pipe, words)
        ph = submit_request(qp_h, ft_h, pipe)
        pc = submit_request(qp_c, ft_c, pipe)
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 2
        assert_rows_identical(ph.wait(), ref)
        assert_rows_identical(pc.wait(), ref)


def tiered_cluster(words, k, *, replicas=2, demote="all", **kw):
    cl = FarCluster(k, 8 * 2**20, page_bytes=PAGE, replicas=replicas, **kw)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, schema())
    cl.table_write(cqp, ct, words)
    if demote == "all":
        rep = cl.demote_cold(max_heat_rows=10**9)
        assert "t" in rep
    elif demote == "mixed":         # partition 0 cold, the rest hot
        cl.nodes[0].pool.demote_table(ct.parts[0])
        for j, h in ct.replicas[0].items():
            cl.nodes[j].pool.demote_table(h)
    return cl, cqp, ct


class TestClusterTierParity:
    @pytest.mark.parametrize("k", NODE_COUNTS)
    @pytest.mark.parametrize("tier", ["all", "mixed"])
    def test_selection_over_cold_partitions(self, data, k, tier):
        pipe = VERBS["selection"]
        words = schema().encode(data)
        ref = solo_ref(pipe, words)
        cl, cqp, ct = tiered_cluster(words, k, demote=tier)
        res = cl.submit_request(cqp, ct, pipe).wait().finalize()
        assert_rows_identical(res, ref)

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_group_over_cold_partitions(self, data, k):
        pipe = GROUPED["group"]
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_ref(pipe, words)]).groups
        cl, cqp, ct = tiered_cluster(words, k)
        got = cl.submit_request(cqp, ct, pipe).wait().finalize().groups
        assert set(got) == set(ref)
        for key in ref:
            for a, b in zip(ref[key], got[key]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_kill_mid_stream_over_cold_partition(self, data, k):
        """The ISSUE's marquee failure case: a verb in flight over a COLD
        partition loses its serving node; the gather fails over to the
        replica — which is just as cold — and splices byte-identically."""
        pipe = VERBS["crypt_post"]
        words = schema().encode(data)
        ref = solo_ref(pipe, words)
        cl, cqp, ct = tiered_cluster(words, k)
        for node in cl.nodes:           # every copy everywhere is cold
            for name, ft in node.tables.items():
                assert node.pool.is_tiered(ft), (node.node_id, name)
        pend = cl.submit_request(cqp, ct, pipe)
        cl.fault.kill(k - 1)            # dies AFTER submit, BEFORE drain
        assert_rows_identical(pend.wait().finalize(), ref)
        assert ct.heat.failovers >= 1

    @pytest.mark.parametrize("k", NODE_COUNTS)
    def test_copartitioned_join_cold_probe_and_build(self, data, k):
        rng = np.random.default_rng(3)
        bft = FTable("cust", (Column("k", "i32"), Column("v")), n_rows=40)
        bd = {"k": rng.permutation(64)[:40].astype(np.int32),
              "v": rng.integers(0, 99, 40).astype(np.float32)}
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        jdata = dict(data)
        jdata["c0"] = rng.integers(0, 64, N).astype(np.int32)
        words = schema().encode(jdata)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        alloc_table_mem(qp, b)
        table_write(qp, b, b.encode(bd))
        ref = None
        ft = schema()
        alloc_table_mem(qp, ft)
        table_write(qp, ft, words)
        ref = farview_request(qp, ft, pipe).finalize()

        cl = FarCluster(k, 8 * 2**20, page_bytes=PAGE, replicas=2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, schema(), partitioner="hash",
                                keys=jdata["c0"])
        cl.table_write(cqp, ct, words)
        cb = cl.alloc_table_mem(
            cqp, FTable(bft.name, bft.columns, n_rows=bft.n_rows),
            co_partition=ct, keys=bd["k"])
        cl.table_write(cqp, cb, bft.encode(bd))
        rep = cl.demote_cold(max_heat_rows=10**9)   # probe AND build cold
        assert "t" in rep
        res = cl.submit_request(cqp, ct, pipe).wait().finalize()
        assert_rows_identical(res, ref)


class TestClusterDemoteSweep:
    def test_demote_cold_respects_heat(self, data):
        """The sweep is ledger-driven: a table with recent traffic stays
        hot, the idle one is demoted on every node holding a copy."""
        cl = FarCluster(2, 8 * 2**20, page_bytes=PAGE, replicas=2)
        cqp = cl.open_connection()
        words = schema().encode(data)
        ct_hot = cl.alloc_table_mem(cqp, schema("busy"))
        ct_idle = cl.alloc_table_mem(cqp, schema("idle"))
        cl.table_write(cqp, ct_hot, words)
        cl.table_write(cqp, ct_idle, words)
        cl.submit_request(cqp, ct_hot, VERBS["selection"]).wait()
        rep = cl.demote_cold(max_heat_rows=0)
        assert "idle" in rep and "busy" not in rep
        for i, part in enumerate(ct_idle.parts):
            assert cl.nodes[ct_idle.home[i]].pool.is_tiered(part)
        # the cold table still answers byte-identically
        np.testing.assert_array_equal(
            np.asarray(cl.table_read(cqp, ct_idle)), words)
