"""Online skew-drift rebalancing (PR 5 tentpole).

The contract under test: a `FarCluster` stays BYTE-IDENTICAL to a single
node across the whole rebalancing lifecycle —

(a) planner: drift detection flags a lopsided load, the skew-aware target
    keeps key groups whole and balanced, count balancing moves the
    minimum, steps respect the byte bound;
(b) rekeying writes (`table_write(..., keys=)`) route rows by the captured
    rule: co-location survives the new key column, and a hostile key
    distribution piles onto one node — the induced skew flip;
(c) live migration: verbs in flight at the flip (scattered under the old
    map) still splice exactly; selection/group/regex/crypt parity holds
    after the partitions move; the versioned map bumps per flip;
(d) co-partitioned joins: the build moves in the probe's plan, the
    re-captured rule is shared by identity, and the join stays local and
    exact after the probe's partitions move;
(e) failure: a pool too full for the transient old+new copies rolls back
    without touching the serving map.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FarviewError, FViewNode, alloc_table_mem,
                               farview_request, open_connection, table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column, string_table
from repro.distributed.rebalance import (balance_counts, detect_drift,
                                         drift_ratio, plan_moves,
                                         plan_rebalance, TableHeat)
from repro.kernels import ref as kref

N = 640
K = 4
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
ROW_BYTES = len(COLS) * 4


def make_data(keys, seed=0):
    rng = np.random.default_rng(seed)
    d = {"c0": np.asarray(keys, np.int32)}
    for i in range(1, 8):
        # integer-valued floats: aggregates merge exactly under any order
        d[f"c{i}"] = rng.integers(-50, 50, len(keys)).astype(np.float32)
    return d


def solo_run(pipe, words, build=None):
    node = FViewNode(64 * 2**20)
    qp = open_connection(node)
    if build is not None:
        bft, bwords = build
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        alloc_table_mem(qp, b)
        table_write(qp, b, bwords)
    ft = FTable("t", COLS, n_rows=words.shape[0])
    alloc_table_mem(qp, ft)
    table_write(qp, ft, words)
    return farview_request(qp, ft, pipe).finalize()


def assert_rows_identical(res, ref):
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes
    assert res.read_bytes == ref.read_bytes


def hot_cluster(seed=0):
    """A hash-partitioned cluster table driven through an induced skew
    flip: every rewritten key belongs to node 0 under the stale rule.
    Returns (cluster, cqp, ctable, new words, new keys)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 64, N).astype(np.int32)
    words = FTable("t", COLS, n_rows=N).encode(make_data(keys, seed))
    cl = FarCluster(K)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                            partitioner="hash", keys=keys)
    cl.table_write(cqp, ct, words)
    owners = ct.co_spec.owners_of(np.arange(64))
    hot = np.arange(64)[owners == 0]
    new_keys = hot[rng.integers(0, len(hot), N)].astype(np.int32)
    new_words = FTable("t", COLS, n_rows=N).encode(
        make_data(new_keys, seed + 1))
    cl.table_write(cqp, ct, new_words, keys=new_keys)
    return cl, cqp, ct, new_words, new_keys


class TestPlanner:
    def test_drift_ratio(self):
        assert drift_ratio([100, 100, 100, 100]) == 1.0
        assert drift_ratio([400, 0, 0, 0]) == 4.0
        assert drift_ratio([]) == 1.0
        assert drift_ratio([0, 0]) == 1.0

    def test_detect_drift_prefers_heat_over_sizes(self):
        heat = TableHeat.zeros(2)
        cold = detect_drift("t", heat, [10, 90], threshold=1.5)
        assert cold.drifted and cold.ratio == pytest.approx(1.8)
        heat.record_dispatch(0, 50)
        heat.record_dispatch(1, 50)
        warm = detect_drift("t", heat, [10, 90], threshold=1.5)
        assert not warm.drifted and warm.ratio == 1.0

    def test_balance_counts_minimal_moves(self):
        parts = [np.arange(90), np.arange(90, 100),
                 np.arange(100, 110), np.arange(110, 120)]
        target = balance_counts(parts)
        assert sorted(len(p) for p in target) == [30, 30, 30, 30]
        # only the overfull node gives rows away
        for i in (1, 2, 3):
            assert set(parts[i]) <= set(target[i])
        got = np.sort(np.concatenate(target))
        np.testing.assert_array_equal(got, np.arange(120))

    def test_plan_moves_bounded_steps(self):
        cur = [np.arange(100), np.zeros(0, np.int64)]
        tgt = [np.arange(50), np.arange(50, 100)]
        steps = plan_moves("t", cur, tgt, row_bytes=32,
                           max_step_bytes=10 * 32)
        assert len(steps) == 5
        assert all(s.n_bytes <= 10 * 32 for s in steps)
        assert all(s.src == 0 and s.dst == 1 for s in steps)
        moved = np.sort(np.concatenate([s.row_ids for s in steps]))
        np.testing.assert_array_equal(moved, np.arange(50, 100))

    def test_plan_rebalance_lpt_keeps_groups_whole(self):
        keys = np.asarray([0] * 300 + [1] * 100 + [2] * 100 + [3] * 140)
        cur = [np.arange(640), np.zeros(0, np.int64),
               np.zeros(0, np.int64), np.zeros(0, np.int64)]
        plan = plan_rebalance("t", cur, 640, ROW_BYTES, n_nodes=4,
                              keys=keys)
        owner = np.full(640, -1)
        for i, p in enumerate(plan.target_part_rows):
            owner[np.asarray(p)] = i
        for key in np.unique(keys):
            assert len(np.unique(owner[keys == key])) == 1
        sizes = sorted(len(p) for p in plan.target_part_rows)
        assert sizes == [100, 100, 140, 300]    # LPT: heavy group alone
        assert plan.new_spec is not None and plan.new_spec.kind == "skew"

    def test_plan_rejects_mismatched_maps(self):
        with pytest.raises(ValueError, match="same rows"):
            plan_moves("t", [np.arange(10)], [np.arange(8)], 32)

    def test_plan_rejects_short_keys(self):
        with pytest.raises(ValueError, match="cover"):
            plan_rebalance("t", [np.arange(10)], 10, 32, n_nodes=1,
                           keys=np.arange(4))


class TestSkewFlip:
    def test_rekey_routes_by_captured_rule(self):
        cl, cqp, ct, words, keys = hot_cluster()
        # the stale hash rule piles every new key onto node 0
        assert ct.part_sizes[0] == N
        assert ct.version == 1
        # co-location still holds (equal keys share a node)
        owner = np.full(N, -1)
        for i, p in enumerate(ct.part_rows):
            owner[np.asarray(p)] = i
        for key in np.unique(keys):
            assert len(np.unique(owner[keys == key])) == 1

    def test_rekey_keeps_results_identical(self):
        cl, cqp, ct, words, keys = hot_cluster()
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              solo_run(pipe, words))

    def test_heat_and_detector_flag_the_hot_node(self):
        cl, cqp, ct, words, keys = hot_cluster()
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        for _ in range(3):
            cl.farview_request(cqp, ct, pipe).finalize()
        assert ct.heat.rows_touched[0] == 3 * N
        assert ct.heat.rows_touched[1:].sum() == 0
        assert ct.heat.bytes_shipped[0] > 0
        report = cl.check_drift()["t"]
        # everything on one node while an LPT re-place could spread it:
        # the ratio is the winnable straggler factor (~K, less LPT noise)
        assert report.drifted and report.ratio > 0.8 * K

    def test_rebalance_restores_balance_and_parity(self):
        cl, cqp, ct, words, keys = hot_cluster()
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        ref = solo_run(pipe, words)
        cl.farview_request(cqp, ct, pipe).finalize()
        plans = cl.auto_rebalance(cqp)
        assert "t" in plans and plans["t"].n_moved > 0
        assert drift_ratio(ct.part_sizes) < 1.2
        assert ct.partitioner == "skew" and ct.co_spec.kind == "skew"
        assert cl.check_drift()["t"].ratio < 1.2     # heat was reset
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_intrinsic_skew_is_not_drift(self):
        """A heavy-hitter key group cannot be split: the LPT-optimal
        placement is lopsided by nature and must read ~1.0, so periodic
        auto_rebalance sweeps leave it alone instead of re-migrating a
        no-op plan forever."""
        rng = np.random.default_rng(23)
        keys = np.concatenate([np.zeros(int(N * 0.6), np.int64),
                               rng.integers(1, 20, N - int(N * 0.6))])
        words = FTable("t", COLS, n_rows=N).encode(make_data(keys, 23))
        cl = FarCluster(K)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                                partitioner="skew", keys=keys)
        cl.table_write(cqp, ct, words)
        report = cl.check_drift()["t"]           # cold: sizes fallback
        assert not report.drifted and report.ratio == pytest.approx(1.0)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        cl.farview_request(cqp, ct, pipe).finalize()
        assert not cl.check_drift()["t"].drifted  # warm: heat, same verdict
        v0 = ct.version
        assert cl.auto_rebalance(cqp) == {}
        assert ct.version == v0

    def test_noop_rebalance_moves_no_pages(self):
        """Rebalancing an already-optimal probe + co-build swaps the rule
        object (identity keeps locality checks passing) without reading,
        copying, or reallocating a single page."""
        rng = np.random.default_rng(29)
        pkeys = rng.integers(0, 64, N).astype(np.int32)
        words = FTable("t", COLS, n_rows=N).encode(make_data(pkeys, 29))
        bft = FTable("dim", (Column("k", "i32"), Column("v")), n_rows=32)
        bkeys = rng.permutation(64)[:32].astype(np.int32)
        bwords = bft.encode({"k": bkeys,
                             "v": rng.integers(0, 9, 32).astype(np.float32)})
        cl = FarCluster(K)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                                partitioner="skew", keys=pkeys)
        cl.table_write(cqp, ct, words)
        cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bkeys)
        cl.table_write(cqp, cb, bwords)
        read_before = cl.stats.bytes_read
        v0, bv0 = ct.version, cb.version
        plan = cl.rebalance(cqp, ct)
        assert plan.empty
        assert cl.stats.bytes_read == read_before     # no copy traffic
        assert ct.version == v0 and cb.version == bv0  # map untouched
        assert ct.co_spec is cb.co_spec is plan.new_spec  # rule re-captured
        pipe = (op.JoinSmall(probe_key="c0", build_table="dim",
                             build_key="k", build_cols=("v",)),)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              solo_run(pipe, words, build=(bft, bwords)))

    def test_rekey_requires_key_rule(self):
        cl = FarCluster(2)
        cqp = cl.open_connection()
        words = FTable("t", COLS, n_rows=N).encode(
            make_data(np.zeros(N, np.int32)))
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N))  # range
        cl.table_write(cqp, ct, words)
        with pytest.raises(ValueError, match="key rule"):
            cl.table_write(cqp, ct, words, keys=np.zeros(N, np.int32))


class TestLiveMigration:
    PIPES = {
        "selection": (op.Select((op.Predicate("c1", "<", 0.0),
                                 op.Predicate("c2", ">", -20.0))),),
        "crypt_post": (op.Select((op.Predicate("c2", ">", 0.0),)),
                       op.Crypt(key=(3, 9), nonce=4, when="post")),
    }

    @pytest.mark.parametrize("name", sorted(PIPES))
    def test_in_flight_requests_splice_under_old_map(self, name):
        """Verbs queued before the flip are dispatched mid-migration and
        must splice with the map they were scattered under."""
        pipe = self.PIPES[name]
        cl, cqp, ct, words, keys = hot_cluster()
        ref = solo_run(pipe, words)
        pend = cl.submit_request(cqp, ct, pipe)      # queued, not flushed
        v0 = ct.version
        plan = cl.rebalance(cqp, ct)
        assert ct.version > v0 and pend.version == v0
        assert_rows_identical(pend.wait().finalize(), ref)
        after = cl.submit_request(cqp, ct, pipe)
        assert after.version == ct.version
        assert_rows_identical(after.wait().finalize(), ref)
        assert plan.n_moved > 0

    def test_group_aggregate_parity_after_migration(self):
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
        cl, cqp, ct, words, keys = hot_cluster()
        ref = solo_run(pipe, words)
        from repro.core.client import merge_group_partials
        ref_groups = merge_group_partials(
            FTable("t", COLS, n_rows=N), pipe, [ref]).groups
        pend = cl.submit_request(cqp, ct, pipe)
        cl.rebalance(cqp, ct)
        for res in (pend.wait().finalize(),
                    cl.farview_request(cqp, ct, pipe).finalize()):
            got = res.groups
            assert set(got) == set(ref_groups)
            for key in ref_groups:
                rc, rs, rmn, rmx = ref_groups[key]
                cc, cs, cmn, cmx = got[key]
                assert rc == cc
                np.testing.assert_array_equal(np.asarray(rs),
                                              np.asarray(cs))

    def test_crypt_pre_parity_after_migration(self):
        """Encrypted-at-rest rows: the keystream is addressed by ORIGINAL
        row offsets, so decryption survives rows changing nodes."""
        key, nonce = (11, 22), 7
        pipe = (op.Crypt(key=key, nonce=nonce, when="pre"),
                op.Select((op.Predicate("c1", "<", 0.0),)))
        rng = np.random.default_rng(3)
        keys = rng.integers(0, 64, N).astype(np.int32)
        words = FTable("t", COLS, n_rows=N).encode(make_data(keys, 3))
        flat = jnp.asarray(words.reshape(-1))
        enc = np.asarray(kref.ctr_crypt(
            flat.view(jnp.uint32), jnp.asarray(key, jnp.uint32), nonce)
        ).view(np.float32).reshape(words.shape)
        ref = solo_run(pipe, enc)
        assert ref.count > 0
        cl = FarCluster(K)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                                partitioner="hash", keys=keys)
        cl.table_write(cqp, ct, enc)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)
        cl.rebalance(cqp, ct, keys=keys)        # re-place by LPT
        assert ct.version > 0
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_regex_mask_parity_after_migration(self):
        """String shells carry no pool data; migration re-shapes the
        shells and the per-request byte scatter follows the new map."""
        strs = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
                b"errr", b"the error is late"]
        rng = np.random.default_rng(5)
        picks = [strs[j] for j in rng.integers(0, len(strs), 300)]
        ft, mat, lens = string_table("s", picks, 24)
        pipe = (op.RegexMatch("error"),)
        node = FViewNode(64 * 2**20)
        qp = open_connection(node)
        part = FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                      str_width=ft.str_width)
        alloc_table_mem(qp, part)
        ref = farview_request(qp, part, pipe,
                              strings=mat, lengths=lens).finalize()
        skeys = rng.integers(0, 16, 300).astype(np.int32)
        cl = FarCluster(3)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(
            cqp, FTable(ft.name, ft.columns, n_rows=ft.n_rows,
                        str_width=ft.str_width),
            partitioner="hash", keys=skeys)
        res = cl.farview_request(cqp, ct, pipe,
                                 strings=mat, lengths=lens).finalize()
        np.testing.assert_array_equal(np.asarray(res.mask),
                                      np.asarray(ref.mask))
        cl.rebalance(cqp, ct, keys=skeys)
        res2 = cl.farview_request(cqp, ct, pipe,
                                  strings=mat, lengths=lens).finalize()
        np.testing.assert_array_equal(np.asarray(res2.mask),
                                      np.asarray(ref.mask))
        assert res2.shipped_bytes == ref.shipped_bytes

    def test_bounded_steps_flip_incrementally(self):
        cl, cqp, ct, words, keys = hot_cluster()
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        ref = solo_run(pipe, words)
        v0 = ct.version
        step_bytes = 64 * ROW_BYTES
        plan = cl.rebalance(cqp, ct, max_step_bytes=step_bytes)
        assert len(plan.steps) > 1
        assert all(s.n_bytes <= step_bytes for s in plan.steps)
        # one map flip per step (versioned map is the migration journal)
        assert ct.version == v0 + len(plan.steps)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_count_balancing_for_range_tables(self):
        """No key rule: rebalance moves the minimum rows to even counts
        (forced lopsided via a hand-built map through the step executor)."""
        cl = FarCluster(2)
        cqp = cl.open_connection()
        words = FTable("t", COLS, n_rows=N).encode(
            make_data(np.arange(N) % 7))
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N))  # range
        cl.table_write(cqp, ct, words)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        ref = solo_run(pipe, words)
        # drain node 1 onto node 0 by planning against a lopsided target
        from repro.distributed.rebalance import MigrationStep
        move = np.asarray(ct.part_rows[1])
        cl._apply_step(cqp, ct, MigrationStep(
            "t", 1, 0, move, len(move) * ROW_BYTES))
        assert ct.part_sizes == [N, 0]
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)
        plan = cl.rebalance(cqp, ct)
        assert plan.new_spec is None and plan.n_moved == N // 2
        assert ct.part_sizes == [N // 2, N // 2]
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_migration_traffic_is_accounted(self):
        cl, cqp, ct, words, keys = hot_cluster()
        before = cl.stats.bytes_read
        plan = cl.rebalance(cqp, ct)
        assert plan.total_bytes > 0
        # the copy went through the pool read path and billed at least
        # the moved payload
        assert cl.stats.bytes_read - before >= plan.total_bytes

    def test_rollback_on_pool_exhaustion(self):
        """A pool too full for the transient old+new copy must fail the
        step WITHOUT corrupting the serving map."""
        cl = FarCluster(2, 8 * 2**20)           # 4 x 2 MiB pages per node
        cqp = cl.open_connection()
        n = 120000                               # ~3.7 MiB -> 1 page short
        rngk = np.random.default_rng(0)
        keys = rngk.integers(0, 64, n).astype(np.int32)
        words = FTable("t", COLS, n_rows=n).encode(make_data(keys))
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=n),
                                partitioner="hash", keys=keys)
        cl.table_write(cqp, ct, words)
        # the all-equal rekey target is the LPT's least-loaded node 0:
        # fill it so the incoming copy cannot allocate
        hog = FTable("hog", COLS, n_rows=120000)
        cl.nodes[0].pool.alloc_table(hog)
        sizes = list(ct.part_sizes)
        version = ct.version
        spec = ct.co_spec
        with pytest.raises(MemoryError):
            cl.rebalance(cqp, ct, keys=np.zeros(n, np.int32))
        assert ct.part_sizes == sizes and ct.version == version
        # zero steps completed: the old key rule is still exact and stays
        assert ct.co_spec is spec
        # node name catalogs must point back at the still-serving shards
        # (join build resolution must never see freed pages)
        for node, part in zip(cl.nodes, ct.parts):
            if part is not None:
                assert node.tables[part.name] is part
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              solo_run(pipe, words))


class TestCoPartitionedJoin:
    def _setup(self, seed=11):
        rng = np.random.default_rng(seed)
        pkeys = rng.integers(0, 64, N).astype(np.int32)
        words = FTable("t", COLS, n_rows=N).encode(make_data(pkeys, seed))
        bft = FTable("dim", (Column("k", "i32"), Column("v")), n_rows=40)
        bkeys = rng.permutation(64)[:40].astype(np.int32)
        bwords = bft.encode({"k": bkeys,
                             "v": rng.integers(0, 99, 40).astype(np.float32)})
        pipe = (op.JoinSmall(probe_key="c0", build_table="dim",
                             build_key="k", build_cols=("v",)),)
        cl = FarCluster(K)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                                partitioner="hash", keys=pkeys)
        cl.table_write(cqp, ct, words)
        cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bkeys)
        cl.table_write(cqp, cb, bwords)
        ref = solo_run(pipe, words, build=(bft, bwords))
        return cl, cqp, ct, cb, pipe, ref, bkeys

    def test_build_moves_in_probe_plan(self):
        cl, cqp, ct, cb, pipe, ref, bkeys = self._setup()
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)
        build_sizes = list(cb.part_sizes)
        plan = cl.rebalance(cqp, ct)
        assert plan.co_tables == ("dim",)
        # the re-captured rule is shared BY IDENTITY: locality still passes
        assert ct.co_spec is cb.co_spec
        assert cb.version == 1 and cb.partitioner == "co[skew]"
        # the build genuinely moved with the rule
        assert list(cb.part_sizes) != build_sizes or plan.n_moved == 0
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_join_in_flight_across_group_flip(self):
        cl, cqp, ct, cb, pipe, ref, bkeys = self._setup(seed=13)
        pend = cl.submit_request(cqp, ct, pipe)
        cl.rebalance(cqp, ct)
        assert_rows_identical(pend.wait().finalize(), ref)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              ref)

    def test_build_alone_is_refused(self):
        cl, cqp, ct, cb, pipe, ref, bkeys = self._setup(seed=17)
        with pytest.raises(FarviewError, match="rebalance the probe"):
            cl.rebalance(cqp, cb)

    def test_replicated_is_refused(self):
        cl = FarCluster(2)
        cqp = cl.open_connection()
        words = FTable("t", COLS, n_rows=64).encode(
            make_data(np.zeros(64, np.int32)))
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=64),
                                replicate=True)
        cl.table_write(cqp, ct, words)
        with pytest.raises(ValueError, match="replicated"):
            cl.rebalance(cqp, ct)

    def test_copartition_alloc_after_rebalance_uses_new_rule(self):
        """A build allocated AFTER the probe rebalanced co-locates by the
        re-captured rule."""
        rng = np.random.default_rng(19)
        pkeys = rng.integers(0, 64, N).astype(np.int32)
        words = FTable("t", COLS, n_rows=N).encode(make_data(pkeys, 19))
        cl = FarCluster(K)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=N),
                                partitioner="hash", keys=pkeys)
        cl.table_write(cqp, ct, words)
        cl.rebalance(cqp, ct)
        bft = FTable("dim2", (Column("k", "i32"), Column("v")), n_rows=40)
        bkeys = rng.permutation(64)[:40].astype(np.int32)
        bwords = bft.encode({"k": bkeys,
                             "v": rng.integers(0, 99, 40).astype(np.float32)})
        cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bkeys)
        cl.table_write(cqp, cb, bwords)
        pipe = (op.JoinSmall(probe_key="c0", build_table="dim2",
                             build_key="k", build_cols=("v",)),)
        assert_rows_identical(cl.farview_request(cqp, ct, pipe).finalize(),
                              solo_run(pipe, words, build=(bft, bwords)))
