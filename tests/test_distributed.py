"""Distribution tests on a forced 8-device CPU mesh (own process group).

Run in a subprocess so XLA_FLAGS=--xla_force_host_platform_device_count=8
doesn't leak into other tests (jax locks device count at first init).
"""
import json
import os
import subprocess
import sys
import textwrap


_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str) -> dict:
    """Run `body` (python source) with 8 forced CPU devices; the script must
    print a single JSON line starting with RESULT:."""
    src = ("import os\n"
           "os.environ['XLA_FLAGS']="
           "'--xla_force_host_platform_device_count=8'\n"
           + textwrap.dedent(body))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in: {out.stdout[-2000:]}")


def test_far_naive_local_equivalence():
    """FV == RCPU == LCPU decode logits on a (2,4) mesh (paper's triad)."""
    res = run_in_subprocess("""
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import smoke_config
    from repro.models.lm import LM
    from repro.launch.mesh import make_test_mesh, set_mesh

    key = jax.random.PRNGKey(0)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = smoke_config(get_config("granite-3-2b"))
    lm_far = LM(cfg, mesh=mesh, dp_axes=("data",))
    lm_loc = LM(cfg)
    params = lm_far.init(key)
    B, MAX_S = 4, 128
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    outs = {}
    with set_mesh(mesh):
        for mode, lm in [("far", lm_far), ("naive", lm_far),
                         ("local", lm_loc)]:
            c = lm.init_cache(B, MAX_S, jnp.float32)
            lg, c = lm.decode_step(params, c, {"tokens": toks},
                                   jnp.int32(0), jnp.int32(0), mode=mode)
            lg, c = lm.decode_step(params, c, {"tokens": toks},
                                   jnp.int32(1), jnp.int32(1), mode=mode)
            outs[mode] = np.asarray(lg[:, -1])
    e_fn = float(np.max(np.abs(outs["far"] - outs["naive"])))
    e_fl = float(np.max(np.abs(outs["far"] - outs["local"])))
    print("RESULT:" + json.dumps({"far_naive": e_fn, "far_local": e_fl}))
    """)
    assert res["far_naive"] < 2e-4
    assert res["far_local"] < 2e-4


def test_sharded_train_step_matches_single_device():
    """One GSPMD train step on (2,2,2) pod mesh == unsharded step."""
    res = run_in_subprocess("""
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.configs.base import TrainConfig, smoke_config
    from repro.models.lm import LM
    from repro.launch.mesh import make_test_mesh, set_mesh
    from repro.distributed import sharding as S
    from repro.runtime import steps as R

    cfg = smoke_config(get_config("granite-3-2b")).replace(remat=False)
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1)
    B, SQ = 8, 32
    batch = {"tokens": jax.random.randint(key, (B, SQ), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, SQ), 0, cfg.vocab)}

    # single-device reference
    lm0 = LM(cfg)
    params0 = lm0.init(key)
    step0 = jax.jit(R.make_train_step(lm0, tcfg))
    opt0 = R.init_train_state(lm0, tcfg, params0)
    p0, o0, m0 = step0(params0, opt0, batch)

    # sharded: multi-pod style mesh (2,2,2)
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    lm = LM(cfg, mesh=mesh)
    pspecs = S.param_specs(jax.eval_shape(lm.init, key), mesh, cfg)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params = jax.device_put(params0, psh)
    opt = R.init_train_state(lm, tcfg, params)
    bspecs = S.batch_specs(cfg, type("S", (), {
        "kind": "train", "seq_len": SQ, "global_batch": B})(), mesh)
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
    batch_sh = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
    step = jax.jit(R.make_train_step(lm, tcfg))
    with set_mesh(mesh):
        p1, o1, m1 = step(params, opt, batch_sh)

    dloss = abs(float(m0["loss"]) - float(m1["loss"]))
    # param drift between the two runs
    da = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
    print("RESULT:" + json.dumps({"dloss": dloss, "dparam": da}))
    """)
    assert res["dloss"] < 5e-3
    assert res["dparam"] < 5e-2   # adam eps-scale differences only


def test_grad_accumulation_equivalence():
    """microbatched train step == full-batch step (grad accumulation)."""
    res = run_in_subprocess("""
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import TrainConfig, smoke_config
    from repro.models.lm import LM
    from repro.runtime import steps as R

    cfg = smoke_config(get_config("granite-3-2b")).replace(
        remat=False, param_dtype="float32")
    key = jax.random.PRNGKey(0)
    lm = LM(cfg)
    params = lm.init(key)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    s1 = jax.jit(R.make_train_step(lm, tcfg, microbatches=1))
    s4 = jax.jit(R.make_train_step(lm, tcfg, microbatches=4))
    o1 = R.init_train_state(lm, tcfg, params)
    o4 = R.init_train_state(lm, tcfg, params)
    p1, _, m1 = s1(params, o1, batch)
    p4, _, m4 = s4(params, o4, batch)
    dloss = abs(float(m1["loss"]) - float(m4["loss"]))
    dp = max(float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    print("RESULT:" + json.dumps({"dloss": dloss, "dparam": dp}))
    """)
    assert res["dloss"] < 1e-4
    assert res["dparam"] < 1e-4


def test_dryrun_single_cell_and_hlo_analysis():
    """The dry-run machinery itself: lower+compile one small cell on the
    512-device production mesh and check the roofline record is complete."""
    res = run_in_subprocess("""
    import json
    import os
    os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'
    from repro.launch import dryrun as D
    rec = D.run_cell("xlstm-125m", "decode_32k", "pod")
    rec["roofline"] = D.roofline_terms(rec)
    out = {"status": rec["status"], "n_chips": rec["n_chips"],
           "dom": rec["roofline"]["dominant"],
           "has_terms": all(k in rec["roofline"] for k in
                            ("t_compute_s", "t_memory_s",
                             "t_collective_s"))}
    print("RESULT:" + json.dumps(out))
    """)
    assert res["status"] == "ok"
    assert res["n_chips"] == 256
    assert res["has_terms"]


def test_hlo_analyzer_trip_scaling():
    """while-loop bodies scale by trip count (raw cost_analysis doesn't)."""
    res = run_in_subprocess("""
    import json
    import jax, jax.numpy as jnp
    from repro.jax_compat import cost_analysis
    from repro.launch.hlo_analysis import analyze
    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    compiled = jax.jit(scanned).lower(x, w).compile()
    a = analyze(compiled.as_text())
    raw = cost_analysis(compiled)["flops"]
    print("RESULT:" + json.dumps({"scaled": a["flops"], "raw": raw}))
    """)
    expect = 10 * 2 * 256 ** 3
    assert abs(res["scaled"] / expect - 1.0) < 0.05
    assert res["raw"] < expect / 5          # documents the undercount
