"""Chaos soak (PR 9 tentpole): seeded socket faults change NOTHING.

The contract extends PR 8's parity claim to a HOSTILE network. Every
Farview verb, run through `ChaosProxy` — a seeded socket-level fault
injector sitting between every `RemoteNodeHandle` and its
`FViewServer` — still answers BYTE-IDENTICALLY to the in-process
reference, or fails TYPED. There is no third outcome: a corrupted
frame fails the CRC trailer, poisons exactly that connection, and
failover reroutes to the partition's replica; a mid-frame reset or
one-way partition reads as a dead node; a duplicated frame is absorbed
by request-id correlation. Wrong bytes never escape.

Time is part of the contract too (the paper's operator off-loading
only pays if the tail is bounded):

  * deadlines — a request carries a RELATIVE budget over the wire; the
    server sheds expired work before dispatch with a typed
    `DEADLINE_EXCEEDED`, never half-running it, and a cluster query's
    budget decays across its scatter legs instead of resetting.
  * hedges — a primary that exceeds `slow_after_s` mid-flight gets its
    partition re-issued on the cyclic replica; first answer wins
    (byte-identical by construction — results are keyed by captured
    row indices), the primary wins ties.
  * breakers — a node that keeps failing trips a per-node circuit
    breaker OPEN; after the reset window ONE half-open probe decides
    whether service resumes. `RemoteNodeHandle` reconnects through the
    same gate, so a restarted server resumes WITHOUT a cluster heal.

Runs in both PR 8 harness modes (in-thread servers by default,
`FARVIEW_NET_SUBPROCESS=1` for real subprocesses). docs/chaos.md has
the fault vocabulary; benchmarks/bench_chaos.py is the soak's
latency-tail twin.
"""
import os
import socket
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

import test_network as tn
from repro.core import operators as op
from repro.core.client import (DeadlineExceededError, FarviewError,
                               FViewNode, NodeDeadError,
                               merge_group_partials, open_connection)
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable, string_table
from repro.distributed.health import (ALIVE, CLOSED, HALF_OPEN, OPEN,
                                      CircuitBreaker, HealthMonitor)
from repro.net import RemoteNodeHandle, wire
from repro.net.chaos import (CLEAN, ChaosProxy, FaultSchedule,
                             proxied_endpoints)
from repro.net.server import FViewServer

N = tn.N
KEY, NONCE = tn.KEY, tn.NONCE

# the soak schedule: jittered delivery, occasional bit flips and
# duplicated frames — enough to exercise every recovery path without
# killing both replicas of a partition in one query too often
SOAK = FaultSchedule(jitter_s=0.002, corrupt_prob=0.03,
                     duplicate_prob=0.05)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 6):
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return d


# ---------------------------------------------------------------- helpers
def chaos_cluster(servers, *, seed=0, schedule=None, replicas=2,
                  **cluster_kw):
    """A FarCluster whose every connection crosses a ChaosProxy."""
    proxies, endpoints = proxied_endpoints(servers, seed=seed,
                                           schedule=schedule)
    handles = [RemoteNodeHandle(h, p, node_id=i, timeout_s=60.0,
                                reconnect_backoff_s=0.02,
                                reconnect_reset_s=0.05)
               for i, (h, p) in enumerate(endpoints)]
    return FarCluster(nodes=handles, replicas=replicas,
                      **cluster_kw), proxies


def _teardown(cl, proxies, servers):
    for p in proxies or ():
        try:
            p.stop_thread()
        except Exception:       # noqa: BLE001 - a fault test wrecked it
            pass
    for s in servers or ():
        try:
            s.stop()
        except Exception:       # noqa: BLE001
            pass


def _revive_all(cl):
    for i in range(cl.n_nodes):
        cl.health.revive(i)


def run_under_chaos(cl, fn, attempts=8):
    """Retry `fn` through typed faults only. A parity violation (wrong
    bytes) raises AssertionError and is NEVER retried — chaos may cost
    retries, never correctness. Deadline sheds re-raise: time ran out."""
    last = None
    for _ in range(attempts):
        try:
            return fn()
        except DeadlineExceededError:
            raise
        except FarviewError as e:
            last = e
            _revive_all(cl)
            time.sleep(0.06)    # let handle breakers reach HALF_OPEN
    raise last


# ------------------------------------------------- parity under the soak
class TestChaosParity:
    """Every verb, through faulty sockets, at 2 and 4 nodes: byte parity
    or a typed error — never silently wrong results."""

    @pytest.mark.parametrize("n_nodes", [2, 4])
    def test_every_verb_byte_identical(self, n_nodes, data):
        servers = tn.spawn_servers(n_nodes)
        cl = proxies = None
        try:
            cl, proxies = chaos_cluster(
                servers, seed=100 + n_nodes, partitioner="hash",
                replicas=2, dead_after=2)
            cqp = cl.open_connection()
            words = tn.schema().encode(data)

            # build table for the co-partitioned join: replicated
            # everywhere, keyed on the probe table's partition key
            rng = np.random.default_rng(7)
            bft = FTable("cust", (Column("k", "i32"), Column("v")),
                         n_rows=13)
            bwords = bft.encode(
                {"k": np.arange(13, dtype=np.int32),
                 "v": rng.integers(0, 99, 13).astype(np.float32)})
            strs = [b"error: disk full", b"all fine", b"ERROR",
                    b"warn: error", b"errr", b"the error is late"]
            sft, mat, lens = string_table(
                "s", [strs[j] for j in rng.integers(0, len(strs), 300)],
                24)

            # setup runs CLEAN: chaos targets queries, not ingest
            ct = cl.alloc_table_mem(cqp, tn.schema(), keys=data["c0"])
            cl.table_write(cqp, ct, words)
            # CO-PARTITIONED build: each shard lands where the probe
            # table's hash rule put its key, so joins resolve locally
            cb = cl.alloc_table_mem(cqp, bft, co_partition=ct,
                                    keys=np.arange(13, dtype=np.int32))
            cl.table_write(cqp, cb, bwords)
            st = cl.alloc_table_mem(cqp, sft, partitioner="range")

            sel = (op.Select((op.Predicate("c1", "<", 0.0),
                              op.Predicate("c2", ">", -20.0))),)
            grp = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
            crypt = (op.Select((op.Predicate("c2", ">", 0.0),)),
                     op.Crypt(key=(3, 9), nonce=4, when="post"))
            rgx = (op.RegexMatch("error"),)
            join = (op.JoinSmall(probe_key="c0", build_table="cust",
                                 build_key="k", build_cols=("v",)),)

            refs = {
                "sel": tn.solo_run(sel, words),
                "grp": merge_group_partials(
                    tn.schema(), grp, [tn.solo_run(grp, words)]).groups,
                "crypt": tn.solo_run(crypt, words),
                "rgx": tn.solo_run(rgx, None, strings=mat, lengths=lens,
                                   ft=sft),
                "join": tn.solo_run(join, words, build=(bft, bwords)),
            }

            for p in proxies:           # chaos ON
                p.set_schedule(SOAK)

            for name, table, pipe, kw in (
                    ("sel", ct, sel, {}),
                    ("grp", ct, grp, {}),
                    ("crypt", ct, crypt, {}),
                    ("rgx", st, rgx,
                     {"strings": mat, "lengths": lens}),
                    ("join", ct, join, {})):
                res = run_under_chaos(
                    cl, lambda t=table, p=pipe, k=kw:
                    cl.farview_request(cqp, t, p, **k).finalize())
                if name == "grp":
                    got = res.groups
                    assert set(got) == set(refs["grp"])
                    for key in refs["grp"]:
                        for r, c in zip(refs["grp"][key], got[key]):
                            np.testing.assert_array_equal(
                                np.asarray(r), np.asarray(c))
                elif name == "rgx":
                    np.testing.assert_array_equal(
                        np.asarray(res.mask),
                        np.asarray(refs["rgx"].mask))
                    assert res.shipped_bytes == refs["rgx"].shipped_bytes
                else:
                    tn.assert_rows_identical(res, refs[name])

            # the soak actually injected faults (seeded: deterministic)
            assert any(p.fault_log for p in proxies)
        finally:
            _teardown(cl, proxies, servers)


# ------------------------------------------------------------- deadlines
class TestDeadlines:
    """A budget of zero (or one spent in a queue) sheds TYPED — the
    request never half-runs, and sheds are not health strikes."""

    def test_in_process_shed_at_flush_pick(self):
        node = FViewNode(tn.CAPACITY)
        qp = open_connection(node)
        ft = tn.schema()
        node.pool.alloc_table(ft)
        pend = node.submit(qp, ft, (op.Select(
            (op.Predicate("c1", "<", 0.0),)),), deadline_s=0.0)
        with pytest.raises(DeadlineExceededError):
            pend.wait()

    def test_expired_budget_shed_at_server_admission(self, data):
        servers = tn.spawn_servers(1)
        try:
            node = RemoteNodeHandle("127.0.0.1", servers[0].port,
                                    node_id=0)
            qp = node.open_connection()
            ft = tn.schema()
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, tn.schema().encode(data))
            pend = node.submit(qp, ft, (op.Select(
                (op.Predicate("c1", "<", 0.0),)),), deadline_s=0.0)
            with pytest.raises(DeadlineExceededError, match="arrival"):
                pend.wait()
            # the shed was typed, not a transport fault: the conn lives
            assert node.submit(qp, ft, (op.Select(
                (op.Predicate("c1", "<", 0.0),)),)).wait().count >= 0
        finally:
            _teardown(None, (), servers)

    def test_budget_spent_in_server_queue_sheds_pre_dispatch(self, data):
        # a wide batching window guarantees the 50 ms budget dies in
        # the server queue — the shed happens at dispatch pick, typed
        servers = tn.spawn_servers(1, flush_interval_s=0.3)
        try:
            node = RemoteNodeHandle("127.0.0.1", servers[0].port,
                                    node_id=0)
            qp = node.open_connection()
            ft = tn.schema()
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, tn.schema().encode(data))
            pend = node.submit(qp, ft, (op.Select(
                (op.Predicate("c1", "<", 0.0),)),), deadline_s=0.05)
            with pytest.raises(DeadlineExceededError, match="queue"):
                pend.wait()
        finally:
            _teardown(None, (), servers)

    def test_cluster_budget_decays_across_scatter_legs(self, data):
        cl = FarCluster(2, tn.CAPACITY, partitioner="hash")
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, tn.schema(), keys=data["c0"])
        cl.table_write(cqp, ct, tn.schema().encode(data))
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        # a dead budget is refused before the scatter spends anything
        with pytest.raises(DeadlineExceededError):
            cl.farview_request(cqp, ct, pipe, deadline_s=0.0)
        # a tiny budget is split across legs and dies at flush pick —
        # the error is the leg's shed, re-raised (never failover-retried)
        pend = cl.submit_request(cqp, ct, pipe, deadline_s=0.001)
        time.sleep(0.05)
        with pytest.raises(DeadlineExceededError):
            pend.wait()
        # a sane budget still answers byte-identically
        res = cl.farview_request(cqp, ct, pipe, deadline_s=30.0)
        tn.assert_rows_identical(res.finalize(),
                                 tn.solo_run(pipe,
                                             tn.schema().encode(data)))


# --------------------------------------------------------------- hedging
class TestHedging:
    """A slow primary no longer sets the query's tail: the replica is
    hedged mid-flight, the first byte-identical answer wins."""

    def test_slow_primary_hedged_to_replica_in_process(self, data):
        cl = FarCluster(2, tn.CAPACITY, partitioner="hash", replicas=2,
                        slow_after_s=0.08, hedge_after_s=0.08)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, tn.schema(), keys=data["c0"])
        words = tn.schema().encode(data)
        cl.table_write(cqp, ct, words)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        ref = tn.solo_run(pipe, words)
        # warm the jit cache first: the timing below measures the
        # HEDGE, not the first-call compile
        tn.assert_rows_identical(
            cl.farview_request(cqp, ct, pipe).finalize(), ref)
        cl.fault.slow(1, 1.2)           # stall, don't kill, node 1
        t0 = time.monotonic()
        res = cl.farview_request(cqp, ct, pipe).finalize()
        elapsed = time.monotonic() - t0
        tn.assert_rows_identical(res, ref)
        assert elapsed < 1.0, (
            f"hedge should beat the 1.2s stall, took {elapsed:.2f}s")
        # exceeding slow_after_s mid-flight is a recorded strike
        assert cl.health.state(1) != ALIVE

    def test_slow_primary_hedged_over_the_wire(self, data):
        servers = tn.spawn_servers(2)
        cl = proxies = None
        try:
            cl, proxies = chaos_cluster(
                servers, seed=5, partitioner="hash", replicas=2,
                slow_after_s=0.08, hedge_after_s=0.08)
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, tn.schema(), keys=data["c0"])
            words = tn.schema().encode(data)
            cl.table_write(cqp, ct, words)
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            ref = tn.solo_run(pipe, words)
            # warm the servers' jit caches before the timed request
            tn.assert_rows_identical(
                cl.farview_request(cqp, ct, pipe).finalize(), ref)
            # degrade ONE node's network: every frame +0.5s, both ways
            proxies[1].set_schedule(FaultSchedule(delay_s=0.5))
            t0 = time.monotonic()
            res = cl.farview_request(cqp, ct, pipe).finalize()
            elapsed = time.monotonic() - t0
            tn.assert_rows_identical(res, ref)
            assert elapsed < 3.0
            time.sleep(1.2)     # let the stalled drain finish quietly
        finally:
            _teardown(cl, proxies, servers)


# ------------------------------------------------------- circuit breaker
class TestCircuitBreaker:
    def test_lifecycle_closed_open_halfopen(self):
        b = CircuitBreaker(1, open_after=2, reset_after_s=0.05)
        assert b.state(0) == CLOSED and b.allow(0)
        b.record_failure(0)
        assert b.state(0) == CLOSED     # one strike is not an outage
        b.record_failure(0)
        assert b.state(0) == OPEN and not b.allow(0)
        time.sleep(0.06)
        assert b.allow(0)               # the single half-open probe
        assert b.state(0) == HALF_OPEN
        assert not b.allow(0)           # second caller is NOT let through
        b.record_failure(0)             # probe failed: trip again
        assert b.state(0) == OPEN
        time.sleep(0.06)
        assert b.allow(0)
        b.record_success(0)             # probe succeeded: service resumes
        assert b.state(0) == CLOSED and b.allow(0)

    def test_health_monitor_drives_the_breaker(self):
        b = CircuitBreaker(1, open_after=2, reset_after_s=60.0)
        mon = HealthMonitor(1, dead_after=3, breaker=b)
        for _ in range(2):
            mon.record_failure(0, NodeDeadError(0, op="test"))
        assert b.state(0) == OPEN
        mon.revive(0)
        assert b.state(0) == CLOSED

    def test_cluster_routes_around_open_breaker(self, data):
        cl = FarCluster(2, tn.CAPACITY, partitioner="hash", replicas=2)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, tn.schema(), keys=data["c0"])
        words = tn.schema().encode(data)
        cl.table_write(cqp, ct, words)
        # trip node 0's breaker without marking it dead
        for _ in range(cl.breaker.open_after):
            cl.breaker.record_failure(0)
        assert cl.breaker.state(0) == OPEN
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        tn.assert_rows_identical(res, tn.solo_run(pipe, words))


# ------------------------------------------------- reconnect (satellite)
def _spawn_fixed_port(port: int):
    """(Re)start a server on a KNOWN port, in the harness's mode."""
    if tn.USE_SUBPROCESS:
        class _Fixed(tn._ProcServer):
            def __init__(self):     # noqa: D401 - same launch, pinned port
                cmd = [sys.executable, "-m", "repro.net.server",
                       "--port", str(port), "--node-id", "0",
                       "--capacity-mb", str(tn.CAPACITY // 2**20)]
                env = dict(os.environ)
                env["PYTHONPATH"] = (str(tn.REPO / "src") + os.pathsep
                                     + env.get("PYTHONPATH", ""))
                self.proc = subprocess.Popen(
                    cmd, stdout=subprocess.PIPE, env=env, text=True)
                deadline = time.monotonic() + 120
                while True:
                    line = self.proc.stdout.readline()
                    if line.startswith("LISTENING"):
                        self.port = int(line.split()[1])
                        break
                    if not line or time.monotonic() > deadline:
                        self.proc.kill()
                        raise RuntimeError("fixed-port server never came up")
        return _Fixed()

    class _Thread:
        def __init__(self):
            self.srv = FViewServer.start_in_thread(
                port=port, capacity_bytes=tn.CAPACITY)
            self.port = self.srv.port

        def abort(self):
            self.srv.stop_thread(abort=True)

        def stop(self):
            self.srv.stop_thread()
    return _Thread()


class TestReconnect:
    """Satellite (c): kill + restart the server on the SAME port
    mid-workload. The handle's breaker trips while it is down, then a
    single HALF_OPEN probe reconnects — byte-identical service resumes
    with NO new handle and NO cluster heal."""

    def test_handle_survives_server_restart(self, data):
        srv = tn.spawn_servers(1)[0]
        port = srv.port
        node = None
        try:
            node = RemoteNodeHandle("127.0.0.1", port, node_id=0,
                                    reconnect_attempts=2,
                                    reconnect_backoff_s=0.02,
                                    reconnect_reset_s=0.08)
            qp = node.open_connection()
            ft = tn.schema()
            words = tn.schema().encode(data)
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, words)
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            ref = tn.solo_run(pipe, words)
            tn.assert_rows_identical(
                node.submit(qp, ft, pipe).wait(), ref)

            srv.abort()                 # SIGKILL / RST: server is GONE
            srv = None
            with pytest.raises(NodeDeadError):
                node.submit(qp, ft, pipe).wait()    # transport death
            with pytest.raises(NodeDeadError):
                node.submit(qp, ft, pipe).wait()    # reconnect fails...
            # ...tripping the handle's breaker OPEN, so further verbs
            # fast-fail instead of hammering the dead port
            assert node._breaker.state(0) == OPEN
            with pytest.raises(NodeDeadError):
                node.submit(qp, ft, pipe).wait()

            srv = _spawn_fixed_port(port)   # ...and it comes back
            time.sleep(0.1)             # past the breaker reset window
            # next verb is the HALF_OPEN probe: reconnect, re-HELLO,
            # re-open the qp, and serve — the restarted node lost its
            # tables (data recovery is the CLUSTER's job), so re-ingest
            # through the SAME handle and qp, then verify byte parity
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, words)
            tn.assert_rows_identical(
                node.submit(qp, ft, pipe).wait(), ref)
            assert node._breaker.state(0) == CLOSED
        finally:
            if node is not None:
                try:
                    node.close()
                except Exception:       # noqa: BLE001
                    pass
            if srv is not None:
                srv.stop()


# ------------------------------------------------- proxy fault vocabulary
class TestChaosProxyFaults:
    """Each fault in isolation: the failure is TYPED, the recovery is
    byte-identical, and the injection sequence is seed-deterministic."""

    def _node_through_proxy(self, schedule, *, seed=0, timeout_s=60.0,
                            **server_kw):
        srv = tn.spawn_servers(1, **server_kw)[0]
        # the handle always connects CLEAN (a corrupted HELLO would just
        # fail construction); the fault plan arms after, atomically
        proxy = ChaosProxy.start_in_thread(
            "127.0.0.1", srv.port, seed=seed, schedule=CLEAN)
        node = RemoteNodeHandle("127.0.0.1", proxy.port, node_id=0,
                                timeout_s=timeout_s,
                                reconnect_backoff_s=0.02,
                                reconnect_reset_s=0.05)
        proxy.set_schedule(schedule)
        return srv, proxy, node

    def test_corruption_fails_typed_then_recovers(self, data):
        srv, proxy, node = self._node_through_proxy(CLEAN)
        try:
            qp = node.open_connection()
            ft = tn.schema()
            words = tn.schema().encode(data)
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, words)
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            ref = tn.solo_run(pipe, words)
            proxy.set_schedule(FaultSchedule(corrupt_prob=1.0))
            with pytest.raises(FarviewError):
                node.submit(qp, ft, pipe).wait()
            assert any(ev["kind"] == "corrupt" for ev in proxy.fault_log)
            proxy.set_schedule(CLEAN)
            time.sleep(0.06)            # handle breaker reset window
            # the SERVER kept the table; the handle reconnects and the
            # answer is byte-identical — zero wrong bytes throughout
            tn.assert_rows_identical(
                node.submit(qp, ft, pipe).wait(), ref)
        finally:
            node.close()
            _teardown(None, [proxy], [srv])

    def test_mid_frame_reset_reads_as_dead_node(self, data):
        srv, proxy, node = self._node_through_proxy(CLEAN)
        try:
            qp = node.open_connection()
            ft = tn.schema()
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, tn.schema().encode(data))
            # cut the connection 10 bytes into the NEXT frame
            proxy.set_schedule(FaultSchedule(reset_after_bytes=10))
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            with pytest.raises(FarviewError):
                node.submit(qp, ft, pipe).wait()
            assert any(ev["kind"] == "reset" for ev in proxy.fault_log)
        finally:
            node.close()
            _teardown(None, [proxy], [srv])

    def test_one_way_partition_reads_as_dead_node(self, data):
        srv, proxy, node = self._node_through_proxy(CLEAN, timeout_s=1.0)
        try:
            qp = node.open_connection()
            ft = tn.schema()
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, tn.schema().encode(data))
            proxy.set_schedule(FaultSchedule(partition_s2c=True))
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            t0 = time.monotonic()
            with pytest.raises(NodeDeadError):
                node.submit(qp, ft, pipe).wait()
            # the client timeout bounded the stall: no infinite hang
            assert time.monotonic() - t0 < 30.0
            assert any(ev["kind"] == "partition"
                       for ev in proxy.fault_log)
        finally:
            node.close()
            _teardown(None, [proxy], [srv])

    def test_duplicate_frames_are_exactly_once(self, data):
        srv, proxy, node = self._node_through_proxy(
            FaultSchedule(duplicate_prob=1.0))
        try:
            qp = node.open_connection()
            ft = tn.schema()
            words = tn.schema().encode(data)
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, words)
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            ref = tn.solo_run(pipe, words)
            # every frame delivered twice; req-id correlation absorbs
            # the echoes and the answer is still byte-identical
            tn.assert_rows_identical(
                node.submit(qp, ft, pipe).wait(), ref)
            assert any(ev["kind"] == "duplicate"
                       for ev in proxy.fault_log)
        finally:
            node.close()
            _teardown(None, [proxy], [srv])

    def test_same_seed_same_fault_sequence(self, data):
        def one_run(seed):
            srv, proxy, node = self._node_through_proxy(
                FaultSchedule(corrupt_prob=0.5, duplicate_prob=0.5),
                seed=seed, timeout_s=1.0)
            try:
                ft = tn.schema()
                try:
                    node.open_connection()
                    node.pool.alloc_table(ft)
                    node.pool.write_table(ft, tn.schema().encode(data))
                except FarviewError:
                    pass                # corruption may kill the conn
                return [(ev["kind"], ev["detail"])
                        for ev in proxy.fault_log]
            finally:
                node.close()
                _teardown(None, [proxy], [srv])

        log_a, log_b = one_run(42), one_run(42)
        assert log_a == log_b and log_a, (
            "seeded chaos must replay identically")
