"""Hypothesis property tests for the PR 10 page codecs.

Two codec families guard the cold tier, and each property here is a
promise the pool's tiering layer depends on:

  * word-page codec (column-plane bit packing): decode(encode(x)) == x
    for ANY u32 page — arbitrary column counts, phases, widths, value
    distributions (dict-friendly low cardinality, delta-friendly narrow
    spans, incompressible noise, NaN/inf float bitcasts), including
    empty and single-word pages;
  * incompressible pages fall back to raw: encode returns None rather
    than a stream that wouldn't fit the frame (the pool keeps the page
    raw and the tier bit says so);
  * corruption is a typed failure: any bit flipped in the stream or the
    descriptors raises `PageCodecError` (a `FarviewError`) — never
    wrong bytes returned to a caller;
  * block codec (string extents): decode(encode(b)) == b for arbitrary
    byte strings, and any framing/CRC damage raises `PageCodecError`.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.errors import FarviewError, PageCodecError
from repro.distributed import compress as pc

_settings = dict(deadline=None, max_examples=25,
                 suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# word pages: generators
# ---------------------------------------------------------------------------
@st.composite
def _page(draw):
    """One logical page of u32 words with a chosen personality."""
    C = draw(st.integers(1, 12))
    n = draw(st.integers(0, 4096))
    phase = draw(st.integers(0, max(0, C - 1)))
    kind = draw(st.sampled_from(
        ["dict", "delta", "noise", "floats", "const", "mixed"]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if kind == "dict":
        vocab = rng.integers(0, 2**32, draw(st.integers(1, 64)),
                             dtype=np.uint64).astype(np.uint32)
        words = vocab[rng.integers(0, vocab.size, n)]
    elif kind == "delta":
        lo = rng.integers(0, 2**31, dtype=np.uint64)
        words = (lo + rng.integers(0, draw(st.sampled_from(
            [1, 2, 255, 65536])), n, dtype=np.uint64)).astype(np.uint32)
    elif kind == "noise":
        words = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    elif kind == "floats":
        f = rng.normal(size=n).astype(np.float32)
        if n:
            f[rng.integers(0, 2, n, dtype=bool)] = np.float32(np.nan)
            f[0] = np.float32(np.inf)
        words = f.view(np.uint32)
    elif kind == "const":
        words = np.full((n,), rng.integers(0, 2**32, dtype=np.uint64),
                        np.uint32)
    else:   # mixed: per-column personalities (dtype-per-column layout)
        words = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
        cols = (phase + np.arange(n)) % C
        for c in range(C):
            m = cols == c
            if draw(st.booleans()):
                words[m] = rng.integers(0, 7, int(m.sum()),
                                        dtype=np.uint64).astype(np.uint32)
    return words, C, phase


page_strategy = _page()


@settings(**_settings)
@given(page=page_strategy)
def test_word_page_roundtrip_exact(page):
    words, C, phase = page
    plan = pc.encode_word_page(words, C, phase=phase)
    assert plan is not None         # no frame bound given -> always encodes
    out = pc.decode_word_page(plan, C)
    np.testing.assert_array_equal(out, words)


@settings(**_settings)
@given(n=st.sampled_from([0, 1]), C=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_empty_and_single_word_pages(n, C, seed):
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    plan = pc.encode_word_page(words, C)
    out = pc.decode_word_page(plan, C)
    np.testing.assert_array_equal(out, words)
    assert plan.n_words == n


@settings(**_settings)
@given(C=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_incompressible_page_falls_back_to_raw(C, seed):
    """Noise packs at width 32 + slack + dict-free overhead: it can never
    fit back inside its own frame, so the frame-bounded encode must
    return None (the pool keeps the page raw, tier bit RAW)."""
    rng = np.random.default_rng(seed)
    n = 2048
    words = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    assert pc.encode_word_page(words, C, page_words=n) is None
    # unconstrained encode still roundtrips (width-32 verbatim planes)
    plan = pc.encode_word_page(words, C)
    np.testing.assert_array_equal(pc.decode_word_page(plan, C), words)


@settings(**_settings)
@given(page=page_strategy, seed=st.integers(0, 2**31 - 1))
def test_corrupt_stream_raises_typed_error(page, seed):
    words, C, phase = page
    if words.size == 0:
        return
    plan = pc.encode_word_page(words, C, phase=phase)
    rng = np.random.default_rng(seed)
    j = int(rng.integers(0, plan.stream.shape[0]))
    plan.stream = plan.stream.copy()
    plan.stream[j] ^= np.uint32(1 << int(rng.integers(0, 32)))
    with pytest.raises(PageCodecError):
        pc.decode_word_page(plan, C)
    assert issubclass(PageCodecError, FarviewError)


@settings(**_settings)
@given(page=page_strategy,
       field=st.sampled_from(["widths", "bitoff", "base", "modes",
                              "n_words"]))
def test_corrupt_descriptor_raises_typed_error(page, field):
    words, C, phase = page
    if words.size == 0:
        return
    plan = pc.encode_word_page(words, C, phase=phase)
    if field == "n_words":
        plan.n_words += 1
    else:
        arr = getattr(plan, field).copy()
        arr[0] += 1
        setattr(plan, field, arr)
    with pytest.raises(PageCodecError):
        pc.decode_word_page(plan, C)


# ---------------------------------------------------------------------------
# block codec (string extents)
# ---------------------------------------------------------------------------
blob_strategy = st.one_of(
    st.binary(min_size=0, max_size=5000),
    # the padded-string regime the codec targets: text + zero tails
    st.builds(
        lambda seed, n, w: np.concatenate([
            np.frombuffer(np.random.default_rng(seed)
                          .integers(97, 123, (n, w // 2), dtype=np.uint8)
                          .tobytes(), np.uint8).reshape(n, w // 2),
            np.zeros((n, w - w // 2), np.uint8)], axis=1).tobytes(),
        st.integers(0, 2**31 - 1), st.integers(1, 64),
        st.integers(2, 64)),
    # long runs (RLE regime)
    st.builds(lambda b, k: bytes(b) * k,
              st.binary(min_size=1, max_size=8), st.integers(1, 3000)),
)


@settings(**_settings)
@given(data=blob_strategy)
def test_block_codec_roundtrip(data):
    enc = pc.encode_blocks(data)
    assert pc.decode_blocks(enc) == data


@settings(**_settings)
@given(data=st.binary(min_size=1, max_size=2000),
       seed=st.integers(0, 2**31 - 1))
def test_block_codec_corruption_raises(data, seed):
    enc = bytearray(pc.encode_blocks(data))
    rng = np.random.default_rng(seed)
    enc[int(rng.integers(0, len(enc)))] ^= 1 << int(rng.integers(0, 8))
    with pytest.raises(PageCodecError):
        pc.decode_blocks(bytes(enc))


@settings(**_settings)
@given(data=st.binary(min_size=0, max_size=500),
       cut=st.integers(1, 100))
def test_block_codec_truncation_raises(data, cut):
    enc = pc.encode_blocks(data)
    with pytest.raises(PageCodecError):
        pc.decode_blocks(enc[:max(0, len(enc) - cut)])
