"""Concurrent regression tests for the farlint-found races (PR 7).

Each test targets one real finding the lock-discipline pass surfaced in
`src/` and that this PR fixed (rather than baselined):

  * the compile cache (`core/pipeline.py`) did a lock-free double-checked
    read — parallel drains could observe the dict mid-insert;
  * `TableHeat` counters (`distributed/rebalance.py`) were bare numpy
    `+=` — parallel node-drain threads recording into the same ledger
    lost increments, silently skewing the drift detector;
  * `FarCluster.catalog` (`core/cluster.py`) was iterated by
    `check_drift`/`heal`/`snapshot` while alloc/free mutated it —
    "dictionary changed size during iteration" under churn;
  * `HealthMonitor` queries (`distributed/health.py`) read lifecycle
    state unlocked while drain threads transitioned it.

These tests drive the exact thread mix that hits each race. They must
stay exact-assertion (no tolerances): the lock makes the outcome
deterministic, and a tolerance would let the regression back in.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core import operators as op
from repro.core.cluster import ClusterTable, FarCluster
from repro.core.pipeline import cache_info, clear_cache, compile_pipeline
from repro.core.table import Column, FTable
from repro.distributed.health import ALIVE, DEAD, SUSPECT, HealthMonitor
from repro.distributed.rebalance import TableHeat


def run_threads(workers):
    """Start/join `workers`; re-raise the first exception from any."""
    errors: list[BaseException] = []

    def wrap(fn):
        def run():
            try:
                fn()
            except BaseException as e:      # noqa: BLE001 - reported below
                errors.append(e)
        return run

    threads = [threading.Thread(target=wrap(fn)) for fn in workers]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


# ------------------------------------------------------------- compile cache
def test_compile_cache_single_build_under_contention():
    """8 threads race compile_pipeline for one key: every caller must get
    the SAME executable and the cache must hold exactly one entry."""
    clear_cache()
    ft = FTable("cc", tuple(Column(f"c{i}", "f32") for i in range(4)),
                n_rows=64)
    pipe = (op.Select((op.Predicate("c1", ">", 0.0),)),)
    barrier = threading.Barrier(8)
    got: list = []

    def build():
        barrier.wait()
        got.append(compile_pipeline(ft, pipe, interpret=True))

    run_threads([build] * 8)
    assert len(got) == 8
    assert len({id(p) for p in got}) == 1, "cache built duplicate executables"
    assert cache_info() == 1
    clear_cache()


def test_compile_cache_distinct_keys_stay_distinct():
    clear_cache()
    ft = FTable("cc2", tuple(Column(f"c{i}", "f32") for i in range(4)),
                n_rows=64)
    pipes = [(op.Select((op.Predicate(f"c{i}", ">", 0.0),)),)
             for i in range(4)]
    barrier = threading.Barrier(8)
    got: dict[int, list] = {i: [] for i in range(4)}

    def build(i):
        def run():
            barrier.wait()
            for _ in range(5):
                got[i].append(compile_pipeline(ft, pipes[i], interpret=True))
        return run

    run_threads([build(i % 4) for i in range(8)])
    assert cache_info() == 4
    for i in range(4):
        assert len({id(p) for p in got[i]}) == 1
    clear_cache()


# ------------------------------------------------------------------ TableHeat
def test_table_heat_counters_exact_under_parallel_drains():
    """8 'drain threads' record into one ledger; the unlocked += this
    replaces lost increments here. Totals must be EXACT."""
    n_nodes, n_threads, iters = 4, 8, 2000
    heat = TableHeat.zeros(n_nodes)
    barrier = threading.Barrier(n_threads)

    def drain(node):
        def run():
            barrier.wait()
            for _ in range(iters):
                heat.record_dispatch(node, 3)
                heat.record_response(node, 7)
                heat.record_request()
                heat.record_failover(node, 2)
                heat.record_replica_write(node, 5)
        return run

    run_threads([drain(i % n_nodes) for i in range(n_threads)])
    per_node = n_threads // n_nodes * iters
    assert heat.rows_touched.tolist() == [3 * per_node] * n_nodes
    assert heat.bytes_shipped.tolist() == [7 * per_node] * n_nodes
    assert heat.requests == n_threads * iters
    assert heat.replica_rows.tolist() == [2 * per_node] * n_nodes
    assert heat.replica_bytes_written.tolist() == [5 * per_node] * n_nodes
    assert heat.failovers == n_threads * iters


def test_table_heat_reset_races_recorders_without_tearing():
    """reset() concurrent with recorders: counters never go negative and
    end up exactly what was recorded after the last reset completes."""
    heat = TableHeat.zeros(2)
    stop = threading.Event()

    def recorder():
        while not stop.is_set():
            heat.record_dispatch(0, 1)

    def resetter():
        for _ in range(200):
            heat.reset()
        stop.set()

    run_threads([recorder, recorder, resetter])
    snap = heat.rows_snapshot()
    assert (snap >= 0).all()
    heat.reset()
    assert heat.rows_snapshot().tolist() == [0, 0]


# ----------------------------------------------------------- cluster catalog
def test_catalog_survives_concurrent_alloc_free_and_drift_sweeps():
    """Writers register/free page-less tables (pure catalog traffic) while
    readers run check_drift sweeps. Pre-fix, the sweep iterated
    `self.catalog` raw and died with 'dictionary changed size during
    iteration' under exactly this churn."""
    cl = FarCluster(2)
    cqp = cl.open_connection()
    cols = (Column("k", "i32"), Column("v"))
    iters = 300
    done = threading.Event()

    def pageless(name: str) -> ClusterTable:
        return ClusterTable(
            FTable(name, cols, n_rows=0), [None] * cl.n_nodes,
            [np.empty(0, np.int64) for _ in range(cl.n_nodes)], "range")

    def writer(tag):
        def run():
            for i in range(iters):
                ct = cl._register(pageless(f"t{tag}_{i}"))
                cl.free_table_mem(cqp, ct)
        return run

    def reader():
        while not done.is_set():
            reports = cl.check_drift()
            assert all(r.ratio >= 1.0 for r in reports.values())

    def sweep_writers_then_signal():
        run_threads([writer("a"), writer("b")])
        done.set()

    run_threads([sweep_writers_then_signal, reader, reader])
    assert not any(k.startswith("t") for k in cl.catalog)  # all freed

    keeper = cl._register(pageless("keeper"))
    assert cl.catalog["keeper"] is keeper


def test_free_table_mem_is_idempotent_under_race():
    """Two threads double-free one table: the guarded check-then-del must
    not raise and must not delete a successor registered under the name."""
    cl = FarCluster(2)
    cqp = cl.open_connection()
    cols = (Column("k", "i32"), Column("v"))
    for _ in range(50):
        ct = ClusterTable(
            FTable("dup", cols, n_rows=0), [None] * cl.n_nodes,
            [np.empty(0, np.int64) for _ in range(cl.n_nodes)], "range")
        cl._register(ct)
        barrier = threading.Barrier(2)

        def free():
            barrier.wait()
            cl.free_table_mem(cqp, ct)

        run_threads([free, free])
        assert "dup" not in cl.catalog


# -------------------------------------------------------------- HealthMonitor
def test_health_queries_race_lifecycle_writers():
    """Readers poll routing queries while writers drive the lifecycle.
    Every observed state must be a legal lifecycle value, and the final
    (single-threaded) state must be deterministic."""
    n = 4
    mon = HealthMonitor(n, dead_after=3)
    stop = threading.Event()
    legal = {ALIVE, SUSPECT, DEAD}

    def writer(node):
        def run():
            for i in range(500):
                mon.record_failure(node, RuntimeError("strike"))
                mon.heartbeat(node, latency_s=0.001)
                mon.record_success(node)
                if i % 50 == 0:
                    mon.mark_dead(node)
                    mon.revive(node)
        return run

    def reader():
        while not stop.is_set():
            assert set(mon.summary().values()) <= legal
            for i in range(n):
                assert mon.state(i) in legal
            alive, dead = set(mon.alive_nodes()), set(mon.dead_nodes())
            assert alive | dead <= set(range(n))

    def writers_then_signal():
        run_threads([writer(i) for i in range(n)])
        stop.set()

    run_threads([writers_then_signal, reader, reader])
    # single-threaded epilogue: transitions still behave
    for i in range(n):
        mon.record_success(i)
        assert mon.state(i) == ALIVE
    mon.mark_dead(0)
    assert not mon.is_alive(0)
    assert mon.dead_nodes() == [0]
    mon.revive(0)
    assert mon.alive_nodes() == list(range(n))


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
