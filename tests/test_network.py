"""Network-tier parity (PR 8 tentpole): sockets change NOTHING.

The contract: a `FarCluster` over `RemoteNodeHandle`s talking to real
`FViewServer` TCP sockets answers every Farview verb BYTE-IDENTICALLY
to the in-process cluster — selection, projection, smart addressing,
group-aggregate, distinct, regex, crypt (pre and post), join — with
the same shipped/read accounting, the same qp counters, and the same
PR 6 failover semantics across a REAL connection drop (the server's
transport is aborted, or the server process SIGKILLed, mid-stream).

Two harness modes, same tests:

  * default — servers run inside this process on daemon threads
    (`FViewServer.start_in_thread`), fast because jit caches are shared;
  * `FARVIEW_NET_SUBPROCESS=1` — every server is a REAL
    `python -m repro.net.server` subprocess and the kill tests are
    SIGKILL. The CI `server-smoke` lane runs this mode; server logs go
    to `$FARVIEW_NET_LOG_DIR` for the failure artifact.

Backpressure is part of the contract too: past the admission bound a
SUBMIT is answered with a typed `OVERLOADED` frame (`OverloadedError`
client-side), shed requests never half-run, and every accepted request
completes exactly.
"""
import os
import socket
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import operators as op
from repro.core.client import (FViewNode, NodeDeadError, alloc_table_mem,
                               farview_request, merge_group_partials,
                               open_connection, table_write)
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable, string_table
from repro.distributed.health import OverloadedError
from repro.net import RemoteNodeHandle, wire
from repro.net.server import FViewServer

REPO = Path(__file__).resolve().parents[1]
USE_SUBPROCESS = os.environ.get("FARVIEW_NET_SUBPROCESS") == "1"
# NB: Path("") is a truthy PosixPath('.'), so guard on the raw string
_LOG_DIR_ENV = os.environ.get("FARVIEW_NET_LOG_DIR")
LOG_DIR = Path(_LOG_DIR_ENV) if _LOG_DIR_ENV else None

N = 500
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(6))
KEY, NONCE = (11, 22), 7
CAPACITY = 128 * 2**20


# ---------------------------------------------------------------- the harness
class _ThreadServer:
    """A server on a daemon thread in THIS process."""

    def __init__(self, node_id: int, **kw):
        kw.setdefault("capacity_bytes", CAPACITY)
        if LOG_DIR is not None:
            LOG_DIR.mkdir(parents=True, exist_ok=True)
            kw.setdefault("log_path",
                          str(LOG_DIR / f"node{node_id}-thread.log"))
        self.srv = FViewServer.start_in_thread(node_id=node_id, **kw)
        self.port = self.srv.port

    def abort(self) -> None:        # the REAL connection drop: RST every peer
        self.srv.stop_thread(abort=True)

    def stop(self) -> None:
        self.srv.stop_thread()


class _ProcServer:
    """A server as a REAL `python -m repro.net.server` subprocess."""

    def __init__(self, node_id: int, *, capacity_bytes: int = CAPACITY,
                 max_queue_depth: int = 1024,
                 flush_interval_s: float = 0.002, n_regions: int = 6):
        cmd = [sys.executable, "-m", "repro.net.server", "--port", "0",
               "--node-id", str(node_id),
               "--capacity-mb", str(capacity_bytes // 2**20),
               "--regions", str(n_regions),
               "--queue-depth", str(max_queue_depth),
               "--flush-interval-ms", str(flush_interval_s * 1e3)]
        if LOG_DIR is not None:
            LOG_DIR.mkdir(parents=True, exist_ok=True)
            cmd += ["--log", str(LOG_DIR / f"node{node_id}-{os.getpid()}-"
                                           f"{time.monotonic_ns()}.log")]
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        self.proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                     env=env, text=True)
        deadline = time.monotonic() + 120
        while True:
            line = self.proc.stdout.readline()
            if line.startswith("LISTENING"):
                self.port = int(line.split()[1])
                break
            if not line or time.monotonic() > deadline:
                self.proc.kill()
                raise RuntimeError("server subprocess never came up")

    def abort(self) -> None:        # SIGKILL: the kernel drops the sockets
        self.proc.kill()
        self.proc.wait(timeout=30)

    def stop(self) -> None:
        self.proc.terminate()
        try:
            self.proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait(timeout=30)


def spawn_servers(n: int, **kw) -> list:
    cls = _ProcServer if USE_SUBPROCESS else _ThreadServer
    return [cls(node_id=i, **kw) for i in range(n)]


def connect(servers, **cluster_kw) -> FarCluster:
    handles = [RemoteNodeHandle("127.0.0.1", s.port, node_id=i)
               for i, s in enumerate(servers)]
    return FarCluster(nodes=handles, **cluster_kw)


@pytest.fixture(scope="module")
def trio():
    servers = spawn_servers(3)
    yield servers
    for s in servers:
        s.stop()


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 6):
        # integer-valued floats: merges are exact under any order
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return d


def schema(name="t"):
    return FTable(name, COLS, n_rows=N)


def solo_run(pipe, words, build=None, strings=None, lengths=None,
             ft=None):
    """The in-process single-node reference every wire result must match."""
    node = FViewNode(CAPACITY)
    qp = open_connection(node)
    if build is not None:
        bft, bwords = build
        b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
        alloc_table_mem(qp, b)
        table_write(qp, b, bwords)
    part = ft if ft is not None else schema()
    part = FTable(part.name, part.columns, n_rows=part.n_rows,
                  str_width=part.str_width)
    alloc_table_mem(qp, part)
    if words is not None:
        table_write(qp, part, words)
    return farview_request(qp, part, pipe,
                           strings=strings, lengths=lengths).finalize()


def net_run(servers, pipe, words, *, partitioner="range", keys=None,
            build=None, strings=None, lengths=None, ft=None, **cluster_kw):
    """The same verb through real sockets; frees the pool pages after."""
    cl = connect(servers, partitioner=partitioner, **cluster_kw)
    cqp = cl.open_connection()
    tables = []
    try:
        if build is not None:
            bft, bwords = build
            b = FTable(bft.name, bft.columns, n_rows=bft.n_rows)
            cb = cl.alloc_table_mem(cqp, b, replicate=True)
            cl.table_write(cqp, cb, bwords)
            tables.append(cb)
        base = ft if ft is not None else schema()
        ct = cl.alloc_table_mem(cqp, base, keys=keys)
        tables.append(ct)
        if words is not None:
            cl.table_write(cqp, ct, words)
        res = cl.farview_request(cqp, ct, pipe,
                                 strings=strings, lengths=lengths).finalize()
        return res, cl, cqp
    finally:
        for t in tables:
            try:
                cl.free_table_mem(cqp, t)
            except Exception:       # noqa: BLE001 - a kill test broke nodes
                pass


def assert_rows_identical(res, ref):
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes
    assert res.read_bytes == ref.read_bytes


# -------------------------------------------------------- parity, every verb
class TestWireParity:
    """Every operator kind: socket cluster == in-process solo, to the byte."""

    def test_selection_and_counters(self, trio, data):
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),
                           op.Predicate("c2", ">", -20.0))),)
        words = schema().encode(data)
        ref = solo_run(pipe, words)
        res, cl, cqp = net_run(trio, pipe, words)
        assert_rows_identical(res, ref)
        # qp byte counters mirror the server's accounting exactly
        assert cqp.bytes_shipped == ref.shipped_bytes
        assert cqp.bytes_read_pool == ref.read_bytes

    def test_projection(self, trio, data):
        pipe = (op.Project(("c1", "c3")),
                op.Select((op.Predicate("c1", ">", 0.0),)))
        words = schema().encode(data)
        assert_rows_identical(net_run(trio, pipe, words,
                                      partitioner="hash",
                                      keys=data["c0"])[0],
                              solo_run(pipe, words))

    def test_smart_addressing(self, trio, data):
        pipe = (op.SmartAddress(("c2", "c5")),
                op.Select((op.Predicate("c2", "<", 10.0),)))
        words = schema().encode(data)
        assert_rows_identical(net_run(trio, pipe, words)[0],
                              solo_run(pipe, words))

    def test_group_aggregate(self, trio, data):
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_run(pipe, words)]).groups
        res, *_ = net_run(trio, pipe, words, partitioner="hash",
                          keys=data["c0"])
        got = res.groups
        assert set(got) == set(ref)
        for key in ref:
            for r, c in zip(ref[key], got[key]):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(c))

    def test_distinct(self, trio, data):
        pipe = (op.Distinct(("c0",), n_buckets=128),)
        words = schema().encode(data)
        ref = merge_group_partials(schema(), pipe,
                                   [solo_run(pipe, words)]).groups
        res, *_ = net_run(trio, pipe, words, partitioner="hash",
                          keys=data["c0"])
        assert set(res.groups) == set(ref) == set(np.unique(data["c0"]))

    def test_crypt_pre_and_post(self, trio, data):
        import jax.numpy as jnp
        from repro.kernels import ref as kref
        words = schema().encode(data)
        flat = jnp.asarray(np.asarray(words, np.float32).reshape(-1))
        enc = np.asarray(kref.ctr_crypt(
            flat.view(jnp.uint32), jnp.asarray(KEY, jnp.uint32),
            NONCE)).view(np.float32).reshape(np.shape(words))
        pre = (op.Crypt(key=KEY, nonce=NONCE, when="pre"),
               op.Select((op.Predicate("c1", "<", 0.0),)))
        ref = solo_run(pre, enc)
        assert ref.count > 0
        assert_rows_identical(net_run(trio, pre, enc)[0], ref)
        post = (op.Select((op.Predicate("c2", ">", 0.0),)),
                op.Crypt(key=(3, 9), nonce=4, when="post"))
        assert_rows_identical(net_run(trio, post, words,
                                      partitioner="hash",
                                      keys=data["c0"])[0],
                              solo_run(post, words))

    def test_regex_strings(self, trio):
        strs = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
                b"errr", b"the error is late"]
        rng = np.random.default_rng(5)
        ft, mat, lens = string_table(
            "s", [strs[j] for j in rng.integers(0, len(strs), 300)], 24)
        pipe = (op.RegexMatch("error"),)
        ref = solo_run(pipe, None, strings=mat, lengths=lens, ft=ft)
        res, *_ = net_run(trio, pipe, None, strings=mat, lengths=lens,
                          ft=ft)
        np.testing.assert_array_equal(np.asarray(res.mask),
                                      np.asarray(ref.mask))
        assert res.shipped_bytes == ref.shipped_bytes
        assert res.read_bytes == ref.read_bytes

    def test_join_partitioned_probe(self, trio, data):
        rng = np.random.default_rng(3)
        bft = FTable("cust", (Column("k", "i32"), Column("v")), n_rows=40)
        bwords = bft.encode(
            {"k": rng.permutation(64)[:40].astype(np.int32),
             "v": rng.integers(0, 99, 40).astype(np.float32)})
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        jdata = dict(data)
        jdata["c0"] = rng.integers(0, 64, N).astype(np.int32)
        words = schema().encode(jdata)
        ref = solo_run(pipe, words, build=(bft, bwords))
        res, *_ = net_run(trio, pipe, words, partitioner="hash",
                          keys=jdata["c0"], build=(bft, bwords))
        assert_rows_identical(res, ref)

    def test_pool_read_roundtrip_and_stats(self, trio, data):
        """Raw table read + pool stats travel the wire exactly."""
        cl = connect(trio)
        cqp = cl.open_connection()
        words = schema().encode(data)
        ct = cl.alloc_table_mem(cqp, schema())
        try:
            cl.table_write(cqp, ct, words)
            np.testing.assert_array_equal(
                np.asarray(cl.table_read(cqp, ct), np.float32),
                np.asarray(words, np.float32))
            stats = cl.stats
            assert stats.bytes_written >= words.size * 4
        finally:
            cl.free_table_mem(cqp, ct)


# --------------------------------------------------- failover: real RST/KILL
class TestConnectionDropFailover:
    """PR 6 semantics across a REAL dead socket: the kill is a transport
    abort (thread mode) or SIGKILL (subprocess mode), never a mock."""

    def _servers(self):
        return spawn_servers(3)

    def test_selection_kill_mid_stream(self, data):
        servers = self._servers()
        try:
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            words = schema().encode(data)
            ref = solo_run(pipe, words)
            cl = connect(servers, replicas=2)
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema())
            cl.table_write(cqp, ct, words)
            pend = cl.submit_request(cqp, ct, pipe)
            servers[1].abort()          # dies AFTER submit, BEFORE drain
            assert_rows_identical(pend.wait(), ref)
            assert cl.health.dead_nodes() == [1]
        finally:
            for i, s in enumerate(servers):
                if i != 1:
                    s.stop()

    def test_group_aggregate_kill_mid_stream(self, data):
        servers = self._servers()
        try:
            pipe = (op.GroupBy("c0", ("c1",), n_buckets=128),)
            words = schema().encode(data)
            ref = merge_group_partials(schema(), pipe,
                                       [solo_run(pipe, words)]).groups
            cl = connect(servers, replicas=2, partitioner="hash")
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema(), keys=data["c0"])
            cl.table_write(cqp, ct, words)
            pend = cl.submit_request(cqp, ct, pipe)
            servers[0].abort()
            got = pend.wait().groups
            assert set(got) == set(ref)
            for key in ref:
                for r, c in zip(ref[key], got[key]):
                    np.testing.assert_array_equal(np.asarray(r),
                                                  np.asarray(c))
            assert cl.health.dead_nodes() == [0]
        finally:
            for i, s in enumerate(servers):
                if i != 0:
                    s.stop()

    def test_table_read_fails_over(self, data):
        servers = self._servers()
        try:
            words = schema().encode(data)
            cl = connect(servers, replicas=2)
            cqp = cl.open_connection()
            ct = cl.alloc_table_mem(cqp, schema())
            cl.table_write(cqp, ct, words)
            servers[2].abort()
            np.testing.assert_array_equal(
                np.asarray(cl.table_read(cqp, ct), np.float32),
                np.asarray(words, np.float32))
            assert 2 in cl.health.dead_nodes()
        finally:
            for i, s in enumerate(servers):
                if i != 2:
                    s.stop()

    def test_dead_connect_raises_node_dead(self):
        with socket.socket() as s:      # grab a port nobody serves
            s.bind(("127.0.0.1", 0))
            port = s.getsockname()[1]
        with pytest.raises(NodeDeadError):
            RemoteNodeHandle("127.0.0.1", port, node_id=0, timeout_s=2)


# ------------------------------------------------------------- backpressure
class TestBackpressure:
    def test_overload_sheds_typed_and_accepted_complete(self, data):
        """Past the admission bound: typed OVERLOADED (never a hang,
        never a half-run); every admitted request completes exactly."""
        servers = spawn_servers(1, max_queue_depth=4,
                                flush_interval_s=0.25)
        try:
            pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
            words = schema().encode(data)
            ref = solo_run(pipe, words)
            node = RemoteNodeHandle("127.0.0.1", servers[0].port,
                                    node_id=0)
            qp = node.open_connection()
            ft = schema()
            node.pool.alloc_table(ft)
            node.pool.write_table(ft, words)
            pends = [node.submit(qp, ft, pipe) for _ in range(12)]
            shed = completed = 0
            for pend in pends:
                try:
                    res = pend.wait()
                except OverloadedError as e:
                    shed += 1
                    assert e.node_id == 0
                    assert "share" in e.detail or "depth" in e.detail
                else:
                    completed += 1
                    assert_rows_identical(res, ref)
            assert shed >= 1            # the bound actually bit
            assert completed >= 1       # and admitted work finished
            assert shed + completed == 12
            node.close()
        finally:
            servers[0].stop()


# ------------------------------------------- robustness against a live server
class TestLiveProtocolRobustness:
    def test_garbage_poisons_one_conn_not_the_server(self, trio):
        """Garbage bytes get a typed ERROR and THAT conn dropped; a
        well-behaved client on the same server is unaffected."""
        raw = socket.create_connection(("127.0.0.1", trio[0].port),
                                       timeout=30)
        raw.sendall(b"\xde\xad\xbe\xef" * 8)
        hdr = b""
        while len(hdr) < wire.HEADER_SIZE:
            chunk = raw.recv(wire.HEADER_SIZE - len(hdr))
            if not chunk:
                break
            hdr += chunk
        assert len(hdr) == wire.HEADER_SIZE
        ftype, _, length = wire.parse_header(hdr)
        assert ftype == wire.ERROR
        body = b""
        while len(body) < length:
            body += raw.recv(length - len(body))
        err = wire.decode_error(wire.decode_value(body))
        assert isinstance(err, wire.ProtocolError)
        trailer = b""
        while len(trailer) < wire.TRAILER_SIZE:
            chunk = raw.recv(wire.TRAILER_SIZE - len(trailer))
            if not chunk:
                break
            trailer += chunk
        wire.check_crc(hdr, body, trailer)      # server frames carry CRC
        assert raw.recv(1) == b""       # and the poisoned conn is dropped
        raw.close()
        # the server is still fully alive for everyone else
        node = RemoteNodeHandle("127.0.0.1", trio[0].port, node_id=0)
        assert node.dispatches >= 0
        node.close()

    def test_oversized_frame_rejected_typed(self, trio):
        raw = socket.create_connection(("127.0.0.1", trio[0].port),
                                       timeout=30)
        raw.sendall(wire.HEADER.pack(wire.MAGIC, wire.VERSION, wire.SUBMIT,
                                     1, wire.MAX_PAYLOAD + 1))
        hdr = raw.recv(wire.HEADER_SIZE)
        ftype, _, length = wire.parse_header(hdr)
        assert ftype == wire.ERROR
        body = b""
        while len(body) < length:
            body += raw.recv(length - len(body))
        assert isinstance(wire.decode_error(wire.decode_value(body)),
                          wire.ProtocolError)
        wire.check_crc(hdr, body,
                       raw.recv(wire.TRAILER_SIZE, socket.MSG_WAITALL))
        raw.close()

    def test_version_mismatch_is_typed(self, trio):
        raw = socket.create_connection(("127.0.0.1", trio[0].port),
                                       timeout=30)
        raw.sendall(wire.encode_frame(wire.HELLO, 1, {"version": 99}))
        hdr = raw.recv(wire.HEADER_SIZE)
        ftype, _, length = wire.parse_header(hdr)
        assert ftype == wire.ERROR
        body = b""
        while len(body) < length:
            body += raw.recv(length - len(body))
        err = wire.decode_error(wire.decode_value(body))
        assert isinstance(err, wire.ProtocolError)
        assert "version" in str(err)
        wire.check_crc(hdr, body,
                       raw.recv(wire.TRAILER_SIZE, socket.MSG_WAITALL))
        raw.close()
