"""Numerics regression tests: finite gradients everywhere.

The masked-exp pattern `where(mask, exp(lw), 0)` overflows on the masked
branch and produces inf*0=NaN in the BACKWARD (d exp = exp). This silently
corrupted zamba2/xlstm training until the optimizer's non-finite guard
exposed it; the fix masks the exponent before exp. These tests pin it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import smoke_config
from repro.models.lm import LM
from repro.models import frontends as F
from repro.optim import adamw


def tree_nonfinite(g):
    return [jax.tree_util.keystr(path)
            for path, leaf in jax.tree_util.tree_leaves_with_path(g)
            if not bool(jnp.all(jnp.isfinite(leaf)))]


@pytest.mark.parametrize("arch", ARCHS)
def test_finite_gradients_at_init(arch):
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    B, S = 2, 64
    batch = {}
    if cfg.embed_input:
        batch["embeds"] = F.audio_frame_embeddings(cfg, B, S,
                                                   dtype=jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.n_image_tokens:
        batch["image_embeds"] = F.image_patch_embeddings(cfg, B,
                                                         dtype=jnp.float32)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    loss, g = jax.value_and_grad(lm.loss)(params, batch)
    assert np.isfinite(float(loss))
    bad = tree_nonfinite(g)
    assert not bad, f"{arch}: non-finite grads in {bad}"


def test_mamba2_long_decay_gradients():
    """Steep decays (large a, long chunks) must not overflow the masked exp."""
    from repro.models import mamba2 as M2
    key = jax.random.PRNGKey(0)
    p = M2.init_mamba2(key, 64, 4, 16, jnp.float32)
    # bias dt up to make decays steep
    p["dt_bias"] = jnp.full_like(p["dt_bias"], 3.0)
    x = jax.random.normal(key, (2, 128, 64))
    g = jax.grad(lambda p: jnp.sum(M2.mamba2_block(
        x, p, n_heads=4, d_state=16, chunk=64) ** 2))(p)
    assert not tree_nonfinite(g)


def test_mlstm_extreme_gates_gradients():
    from repro.models import xlstm as XL
    key = jax.random.PRNGKey(0)
    p = XL.init_mlstm(key, 64, 4, jnp.float32)
    x = jax.random.normal(key, (2, 128, 64)) * 3.0   # large gate logits
    g = jax.grad(lambda p: jnp.sum(XL.mlstm_block(
        x, p, n_heads=4, chunk=32) ** 2))(p)
    assert not tree_nonfinite(g)


def test_optimizer_skips_nonfinite_update():
    """inf/NaN grads must leave params AND moments untouched (in-graph)."""
    cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=1)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = adamw.init(params)
    good = {"w": jnp.asarray([0.1, 0.1])}
    p1, s1, m1 = adamw.update(cfg, params, good, state)
    assert float(m1["skipped"]) == 0.0
    bad = {"w": jnp.asarray([jnp.inf, 0.1])}
    p2, s2, m2 = adamw.update(cfg, p1, bad, s1)
    assert float(m2["skipped"]) == 1.0
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(p1["w"]))
    np.testing.assert_array_equal(np.asarray(s2["m"]["w"]),
                                  np.asarray(s1["m"]["w"]))
    assert int(s2["step"]) == int(s1["step"]) + 1
    # and everything stays finite afterwards
    p3, s3, m3 = adamw.update(cfg, p2, good, s2)
    assert float(m3["skipped"]) == 0.0
    assert bool(jnp.all(jnp.isfinite(p3["w"])))
