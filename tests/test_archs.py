"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes + no NaNs (assignment spec).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import TrainConfig, smoke_config
from repro.models import frontends as F
from repro.models.lm import LM
from repro.runtime import steps as R

B, S = 2, 64


def make_batch(cfg, key):
    batch = {}
    if cfg.embed_input:
        batch["embeds"] = F.audio_frame_embeddings(cfg, B, S,
                                                   dtype=jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.n_image_tokens:
        batch["image_embeds"] = F.image_patch_embeddings(cfg, B,
                                                         dtype=jnp.float32)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    batch = make_batch(cfg, key)
    logits, aux, _ = lm.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.n_experts:
        assert float(aux) > 0.0            # aux loss live for MoE


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(R.make_train_step(lm, tcfg))
    opt = R.init_train_state(lm, tcfg, params)
    batch = make_batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["adam"]["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma2-9b", "xlstm-125m",
                                  "zamba2-2.7b", "qwen3-moe-30b-a3b",
                                  "musicgen-large", "llama-3.2-vision-11b"])
def test_decode_step(arch):
    """Two decode steps against a fresh cache produce finite logits."""
    cfg = smoke_config(get_config(arch))
    lm = LM(cfg)
    key = jax.random.PRNGKey(2)
    params = lm.init(key)
    cache = lm.init_cache(B, 32, jnp.float32)
    if cfg.n_image_tokens:
        # vlm decode needs the cross-attn cache prefilled
        img = F.image_patch_embeddings(cfg, B, dtype=jnp.float32)
        hd = cfg.resolved_head_dim
        gp0 = jax.tree.map(lambda x: x[0], params["groups"])
        name = [k for k in gp0 if k.startswith("cross")][0]
        # static image KV: same projections every group; fill group 0's
        kimg = jnp.swapaxes((img @ gp0[name]["attn"]["wk"]).reshape(
            B, cfg.n_image_tokens, cfg.n_kv_heads, hd), 1, 2)
        vimg = jnp.swapaxes((img @ gp0[name]["attn"]["wv"]).reshape(
            B, cfg.n_image_tokens, cfg.n_kv_heads, hd), 1, 2)
        g = cache["k_cross"].shape[0]
        cache["k_cross"] = jnp.broadcast_to(kimg[None],
                                            (g,) + kimg.shape).astype(
            cache["k_cross"].dtype)
        cache["v_cross"] = jnp.broadcast_to(vimg[None],
                                            (g,) + vimg.shape).astype(
            cache["v_cross"].dtype)
    if cfg.embed_input:
        batch = {"embeds": F.audio_frame_embeddings(cfg, B, 1,
                                                    dtype=jnp.float32)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache = lm.decode_step(params, cache, batch, jnp.int32(0),
                                   jnp.int32(0), mode="local")
    logits2, cache = lm.decode_step(params, cache, batch, jnp.int32(1),
                                    jnp.int32(1), mode="local")
    assert logits2.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_prefill_decode_consistency():
    """Greedy decode after prefill matches teacher-forced forward argmax."""
    cfg = smoke_config(get_config("granite-3-2b"))
    lm = LM(cfg)
    key = jax.random.PRNGKey(3)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    logits_full, _, _ = lm.forward(params, {"tokens": toks})
    # decode positions 0..7 one at a time
    cache = lm.init_cache(1, 16, jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = lm.decode_step(params, cache, {"tokens": toks[:, t:t+1]},
                                   jnp.int32(t), jnp.int32(t), mode="local")
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_full), rtol=2e-3,
                               atol=2e-3)


def test_recurrent_decode_consistency():
    """xlstm decode steps == full-sequence forward (recurrent state path)."""
    cfg = smoke_config(get_config("xlstm-125m"))
    lm = LM(cfg)
    key = jax.random.PRNGKey(4)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    logits_full, _, _ = lm.forward(params, {"tokens": toks})
    cache = lm.init_cache(1, 16, jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = lm.decode_step(params, cache, {"tokens": toks[:, t:t+1]},
                                   jnp.int32(t), jnp.int32(t), mode="local")
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_full), rtol=5e-2,
                               atol=5e-2)


def test_mamba_decode_consistency():
    cfg = smoke_config(get_config("zamba2-2.7b"))
    lm = LM(cfg)
    key = jax.random.PRNGKey(5)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab)
    logits_full, _, _ = lm.forward(params, {"tokens": toks})
    cache = lm.init_cache(1, 16, jnp.float32)
    outs = []
    for t in range(6):
        lg, cache = lm.decode_step(params, cache, {"tokens": toks[:, t:t+1]},
                                   jnp.int32(t), jnp.int32(t), mode="local")
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_full), rtol=5e-2,
                               atol=5e-2)


def test_gemma2_window_masking():
    """gemma2 local layers must mask beyond the sliding window."""
    cfg = smoke_config(get_config("gemma2-9b")).replace(window=4)
    lm = LM(cfg)
    key = jax.random.PRNGKey(6)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab)
    logits1, _, _ = lm.forward(params, {"tokens": toks})
    # perturb a token far outside every window: position 0 vs query 15
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab)
    logits2, _, _ = lm.forward(params, {"tokens": toks2})
    # global layers still see pos 0, so logits differ; this asserts shape
    # sanity; the window path is covered by the decode sliding-window test
    assert logits1.shape == logits2.shape


def test_moe_routing_mass_conservation():
    """MoE combine weights sum to 1 over selected experts (unit output scale)."""
    from repro.models.moe import moe_ffn, init_moe
    key = jax.random.PRNGKey(7)
    p = init_moe(key, 64, 32, 8, jnp.float32)
    x = jax.random.normal(key, (2, 16, 64))
    out, aux = moe_ffn(x, p, top_k=2, capacity_factor=8.0)  # no drops
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # capacity large enough -> output equals dense-over-topk reference
    logits = (x.reshape(-1, 64) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, e = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    xt = x.reshape(-1, 64)
    ref = np.zeros((32, 64), np.float32)
    for t in range(32):
        acc = 0
        for j in range(2):
            ei = int(e[t, j])
            h = jax.nn.silu(xt[t] @ p["w_gate"][ei]) * (xt[t] @ p["w_up"][ei])
            acc = acc + float(w[t, j]) * np.asarray(h @ p["w_down"][ei])
        ref[t] = acc
    np.testing.assert_allclose(np.asarray(out).reshape(32, 64), ref,
                               rtol=2e-2, atol=2e-2)
