"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles.

Every Pallas kernel runs in interpret=True on CPU (the TPU target is the
BlockSpec structure, validated here for semantics). assert_allclose against
ref.py per the spec.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# select_project
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,a", [(64, 4), (100, 8), (1000, 8), (257, 3),
                                 (4096, 16), (1, 8), (513, 130)])
def test_select_project_shapes(rng, n, a):
    table = rng.normal(size=(n, a)).astype(np.float32)
    sel_ops = np.zeros(a, np.int32)
    sel_vals = np.zeros(a, np.float32)
    sel_ops[0] = kref.OP_LT
    sel_vals[0] = 0.3
    if a > 2:
        sel_ops[2] = kref.OP_GE
        sel_vals[2] = -0.5
    proj = np.zeros(a, np.float32)
    proj[: max(1, a // 2)] = 1
    packed, count = kops.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    rp, rc = kref.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    assert int(count) == int(rc)
    np.testing.assert_allclose(np.asarray(packed)[: int(count)],
                               np.asarray(rp)[: int(rc)], rtol=1e-6)


@pytest.mark.parametrize("op", list(kref.OP_SKIP + 1 + np.arange(6)))
def test_select_every_predicate_op(rng, op):
    n, a = 500, 8
    table = rng.normal(size=(n, a)).astype(np.float32)
    # force exact matches to exist for EQ/NE
    table[::7, 1] = 0.25
    sel_ops = np.zeros(a, np.int32)
    sel_vals = np.zeros(a, np.float32)
    sel_ops[1] = op
    sel_vals[1] = 0.25
    proj = np.ones(a, np.float32)
    packed, count = kops.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    rp, rc = kref.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    assert int(count) == int(rc)
    np.testing.assert_allclose(np.asarray(packed)[: int(count)],
                               np.asarray(rp)[: int(rc)], rtol=1e-6)


def test_select_project_all_and_none(rng):
    n, a = 300, 8
    table = rng.normal(size=(n, a)).astype(np.float32)
    proj = np.ones(a, np.float32)
    # none match
    ops_none = np.zeros(a, np.int32)
    vals = np.zeros(a, np.float32)
    ops_none[0] = kref.OP_GT
    vals[0] = 1e9
    _, count = kops.select_project(jnp.asarray(table), jnp.asarray(ops_none),
                                   jnp.asarray(vals), jnp.asarray(proj))
    assert int(count) == 0
    # all match
    ops_all = np.zeros(a, np.int32)
    _, count = kops.select_project(jnp.asarray(table), jnp.asarray(ops_all),
                                   jnp.asarray(vals), jnp.asarray(proj))
    assert int(count) == n


def test_select_project_stability(rng):
    """Survivors keep their original relative order (stable packing)."""
    n, a = 700, 4
    table = rng.normal(size=(n, a)).astype(np.float32)
    table[:, 3] = np.arange(n, dtype=np.float32)  # order tag (within 2^24)
    sel_ops = np.zeros(a, np.int32)
    sel_vals = np.zeros(a, np.float32)
    sel_ops[0] = kref.OP_GT
    proj = np.ones(a, np.float32)
    packed, count = kops.select_project(
        jnp.asarray(table), jnp.asarray(sel_ops), jnp.asarray(sel_vals),
        jnp.asarray(proj))
    tags = np.asarray(packed)[: int(count), 3]
    assert np.all(np.diff(tags) > 0), "pack must preserve row order"


# ---------------------------------------------------------------------------
# hash_group
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,card,nb", [(256, 10, 64), (1000, 50, 256),
                                       (3000, 200, 512), (100, 5, 1024),
                                       (2048, 2000, 256)])
def test_group_aggregate_exact(rng, n, card, nb):
    keys = rng.integers(0, card, size=n).astype(np.int32)
    vals = rng.normal(size=(n, 3)).astype(np.float32)
    got = kops.group_aggregate_full(jnp.asarray(keys), jnp.asarray(vals),
                                    n_buckets=nb)
    exact = kref.group_aggregate_exact(keys, vals)
    assert set(got) == set(exact)
    for k in exact:
        c, s, mn, mx = got[k]
        ce, se, mne, mxe = exact[k]
        assert c == ce
        np.testing.assert_allclose(s, se, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(mn, mne, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mx, mxe, rtol=1e-5, atol=1e-6)


def test_group_negative_and_large_keys(rng):
    keys = np.array([-5, -5, 3, 1 << 20, 3, -5, 0, 0], np.int32)
    vals = np.ones((8, 1), np.float32)
    got = kops.group_aggregate_full(jnp.asarray(keys), jnp.asarray(vals),
                                    n_buckets=64)
    exact = kref.group_aggregate_exact(keys, vals)
    assert set(got) == set(exact)
    for k in exact:
        assert got[k][0] == exact[k][0]


def test_distinct(rng):
    keys = rng.integers(0, 37, size=900).astype(np.int32)
    got = kops.distinct(jnp.asarray(keys), n_buckets=64)
    assert got == sorted(set(keys.tolist()))


def test_group_overflow_contract(rng):
    """With tiny bucket count, collisions overflow but the kernel+client
    merge is still exact (paper's cuckoo-overflow contract)."""
    keys = rng.integers(0, 500, size=2000).astype(np.int32)
    vals = rng.normal(size=(2000, 2)).astype(np.float32)
    got = kops.group_aggregate_full(jnp.asarray(keys), jnp.asarray(vals),
                                    n_buckets=64)  # 500 keys >> 64 buckets
    exact = kref.group_aggregate_exact(keys, vals)
    assert set(got) == set(exact)
    total_count = sum(v[0] for v in got.values())
    assert total_count == 2000


# ---------------------------------------------------------------------------
# ctr_crypt
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 63, 64, 1000, 32768, 99999])
def test_crypt_roundtrip_and_ref(rng, n):
    data = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
    key = np.array([0xA5A5A5A5, 0x12345678], np.uint32)
    enc = kops.crypt(jnp.asarray(data), key, 7)
    dec = kops.crypt(enc, key, 7)
    np.testing.assert_array_equal(np.asarray(dec), data)
    ref = kref.ctr_crypt(jnp.asarray(data), jnp.asarray(key), 7)
    np.testing.assert_array_equal(np.asarray(enc), np.asarray(ref))


def test_crypt_key_and_nonce_sensitivity(rng):
    data = rng.integers(0, 1 << 32, size=512, dtype=np.uint32)
    k1 = np.array([1, 2], np.uint32)
    k2 = np.array([1, 3], np.uint32)
    e1 = np.asarray(kops.crypt(jnp.asarray(data), k1, 0))
    e2 = np.asarray(kops.crypt(jnp.asarray(data), k2, 0))
    e3 = np.asarray(kops.crypt(jnp.asarray(data), k1, 1))
    assert (e1 != e2).mean() > 0.9
    assert (e1 != e3).mean() > 0.9
    # keystream should look uniform: bit balance within 3 sigma
    bits = np.unpackbits((e1 ^ data).view(np.uint8))
    assert abs(bits.mean() - 0.5) < 3 / (2 * np.sqrt(bits.size))


# ---------------------------------------------------------------------------
# dfa_match
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pattern,cases", [
    ("abc", [b"abc", b"xabcx", b"ab", b"abd", b""]),
    ("ab+c", [b"abc", b"abbbbc", b"ac", b"abb", b"zabbcz"]),
    ("a|b", [b"ccc", b"cac", b"b", b"", b"xyz"]),
    ("(ab)*c", [b"c", b"ababc", b"abab", b"xc", b"abc"]),
    ("a.c", [b"abc", b"a_c", b"ac", b"axxc", b"zzaxczz"]),
    ("[0-9]+", [b"abc123", b"no digits", b"7", b"", b"x9"]),
])
def test_regex_vs_python(pattern, cases):
    import re as pyre
    from repro.core.regex import compile_regex
    from repro.core.table import string_table
    table, accept = compile_regex(pattern)
    ft, mat, lens = string_table("s", list(cases), 24)
    mask = kops.regex_match(jnp.asarray(mat), jnp.asarray(lens),
                            jnp.asarray(table), jnp.asarray(accept))
    expect = [bool(pyre.search(pattern.encode(), s)) for s in cases]
    assert np.asarray(mask).tolist() == expect


def test_regex_vs_ref_oracle(rng):
    from repro.core.regex import compile_regex
    table, accept = compile_regex("b[a-d]+a")
    n, width = 300, 20
    mat = rng.integers(97, 103, size=(n, width)).astype(np.uint8)
    lens = rng.integers(0, width + 1, size=n).astype(np.int32)
    got = kops.regex_match(jnp.asarray(mat), jnp.asarray(lens),
                           jnp.asarray(table), jnp.asarray(accept))
    ref = kref.dfa_match(jnp.asarray(mat), jnp.asarray(lens),
                         jnp.asarray(table), jnp.asarray(accept))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,d,s", [
    (1, 4, 4, 64, 256), (2, 8, 2, 64, 512), (3, 16, 16, 128, 300),
    (2, 8, 1, 128, 1024), (1, 32, 8, 96, 257),
])
def test_decode_attention_vs_ref(rng, b, hq, hkv, d, s):
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    lengths = rng.integers(1, s + 1, size=b).astype(np.int32)
    o, m, l = kops.decode_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), jnp.asarray(lengths))
    ro, rm, rl = kref.decode_attention(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), jnp.asarray(lengths))
    out = np.asarray(o) / np.maximum(np.asarray(l), 1e-30)[..., None]
    rout = np.asarray(ro) / np.maximum(np.asarray(rl), 1e-30)[..., None]
    np.testing.assert_allclose(out, rout, rtol=1e-4, atol=1e-5)


def test_decode_attention_bf16(rng):
    b, hq, hkv, d, s = 2, 8, 2, 64, 512
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    lengths = np.array([500, 31], np.int32)
    o, m, l = kops.decode_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), jnp.asarray(lengths))
    ro, rm, rl = kref.decode_attention(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), jnp.asarray(lengths))
    out = np.asarray(o) / np.maximum(np.asarray(l), 1e-30)[..., None]
    rout = np.asarray(ro) / np.maximum(np.asarray(rl), 1e-30)[..., None]
    np.testing.assert_allclose(out, rout, rtol=0.05, atol=0.05)


def test_partial_merge_equals_full(rng):
    """Sharded partials merged == full attention (the far-KV invariant)."""
    b, hq, hkv, d, s, shards = 2, 8, 2, 64, 1024, 4
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    lengths = np.array([1000, 700], np.int32)
    per = s // shards
    parts = []
    for i in range(shards):
        loc_len = np.clip(lengths - i * per, 0, per).astype(np.int32)
        parts.append(kops.decode_attention(
            jnp.asarray(q), jnp.asarray(k[:, i * per:(i + 1) * per]),
            jnp.asarray(v[:, i * per:(i + 1) * per]), jnp.asarray(loc_len)))
    merged = kref.merge_partials(parts)
    full = kref.full_attention_oracle(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-4, atol=1e-5)
