"""FarPool allocator: deque free lists, striping order, shard exhaustion
fallback, alloc/free/realloc cycles, and the device-resident gather path."""
from collections import deque

import numpy as np
import pytest

from repro.core.pool import FarPool
from repro.core.table import FTable, Column

PB = 4096                      # small pages keep the test pool tiny
COLS = tuple(Column(f"c{i}") for i in range(8))


def tbl(name, n_pages):
    # 8 f32 cols -> 32 B/row -> PB/32 rows fill exactly one page
    return FTable(name, COLS, n_rows=n_pages * PB // 32)


def test_free_lists_are_deques():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)
    assert all(isinstance(f, deque) for f in pool._free)


def test_striping_order_round_robin():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)   # chunks [0..3],[4..7]
    ft = pool.alloc_table(tbl("t", 4))
    assert ft.pages == (0, 4, 1, 5)     # alternating shards, FIFO per shard


def test_shard_exhaustion_fallback():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)
    t1 = pool.alloc_table(tbl("a", 6))
    assert t1.pages == (0, 4, 1, 5, 2, 6)
    # shard 0 has one page left; allocation continues across what remains
    t2 = pool.alloc_table(tbl("b", 2))
    assert t2.pages == (3, 7)
    assert pool.free_pages == 0
    with pytest.raises(MemoryError):
        pool.alloc_table(tbl("c", 1))


def test_alloc_free_realloc_cycles():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)
    free0 = pool.free_pages
    for _ in range(5):
        t1 = pool.alloc_table(tbl("a", 3))
        t2 = pool.alloc_table(tbl("b", 3))
        assert pool.free_pages == free0 - 6
        assert set(t1.pages).isdisjoint(t2.pages)
        pool.free_table(t1)
        pool.free_table(t2)
        assert pool.free_pages == free0
    # freed pages recycle FIFO within their shard: a fresh alloc starts
    # from the lowest-numbered still-striped pages again
    t3 = pool.alloc_table(tbl("c", 2))
    assert {p // pool.chunk for p in t3.pages} == {0, 1}
    pool.free_table(t3)
    assert pool.page_table == {}


def test_realloc_data_integrity_across_shards():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)
    rng = np.random.default_rng(0)
    t1 = pool.alloc_table(tbl("a", 3))
    w1 = rng.normal(size=(t1.n_rows, 8)).astype(np.float32)
    pool.write_table(t1, w1)
    np.testing.assert_array_equal(np.asarray(pool.read_table(t1)), w1)
    pool.free_table(t1)
    t2 = pool.alloc_table(tbl("b", 5))      # reuses + extends the pages
    w2 = rng.normal(size=(t2.n_rows, 8)).astype(np.float32)
    pool.write_table(t2, w2)
    np.testing.assert_array_equal(np.asarray(pool.read_table(t2)), w2)


def test_gather_rows_matches_read_table():
    pool = FarPool(8 * PB, page_bytes=PB, n_shards=2)
    rng = np.random.default_rng(1)
    ft = pool.alloc_table(tbl("a", 4))
    w = rng.normal(size=(ft.n_rows, 8)).astype(np.float32)
    pool.write_table(ft, w)
    before = pool.stats.bytes_read
    got = pool.gather_rows(ft.pages, ft.n_rows, ft.row_words)
    np.testing.assert_array_equal(np.asarray(got), w)
    assert pool.stats.bytes_read == before      # pure read path, no stats
    np.testing.assert_array_equal(np.asarray(pool.read_table(ft)), w)
    assert pool.stats.bytes_read == before + ft.n_bytes
