"""The generalized bucket-batched scheduler (PR 2 tentpole).

Every request kind rides ONE stacked executable per scheduling round:

(a) shape-bucketing — same-layout tables with *different* row counts in one
    power-of-two bucket coalesce (page lists padded with the pool null
    page, tails masked by n_valid), per-request results byte-identical to
    solo dispatch and padded rows excluded from byte accounting;
(b) zero-retrace — K different-sized tables in one bucket cost one trace,
    and later rounds with other sizes in the same bucket cost zero more;
(c) stacked string/regex dispatch — (B, n, w) byte tensor, row/width
    bucketed, masks equal to solo, pre-crypt pins the width (keystream);
(d) stacked join-probe dispatch — same-build probes share one broadcast
    build operand;
(e) close_connection cancels still-queued requests (no ghost dispatch
    against a re-bound region);
(f) the compile cache treats interpret=None and its resolved bool as one
    entry.
"""
import numpy as np
import pytest

from repro.core import operators as op
from repro.core.client import (FarviewError, FViewNode, alloc_table_mem,
                               close_connection, farview_request,
                               merge_group_partials, open_connection,
                               submit_request, table_write)
from repro.core.pipeline import cache_info, clear_cache, compile_pipeline
from repro.core.table import FTable, Column, string_table


def word_table(qp, name, n, seed=0, card=0):
    rng = np.random.default_rng(seed)
    cols = tuple(Column(f"c{i}", "i32" if (i == 0 and card) else "f32")
                 for i in range(8))
    ft = FTable(name, cols, n_rows=n)
    alloc_table_mem(qp, ft)
    data = {}
    for i in range(8):
        if i == 0 and card:
            data["c0"] = rng.integers(0, card, n).astype(np.int32)
        else:
            data[f"c{i}"] = rng.normal(size=n).astype(np.float32)
    table_write(qp, ft, ft.encode(data))
    return ft, data


SIZES = (300, 512, 400)          # all in the 512 bucket, none equal
PIPE = (op.Select((op.Predicate("c1", "<", 0.2),)),)


def solo_refs(sizes, pipe, *, card=0):
    """Reference results from solo dispatch on an independent node."""
    node = FViewNode(64 * 2**20, n_regions=len(sizes))
    out = []
    for i, n in enumerate(sizes):
        qp = open_connection(node)
        ft, data = word_table(qp, f"r{i}", n, seed=100 + i, card=card)
        out.append((farview_request(qp, ft, pipe).finalize(), data))
    return out


class TestShapeBucketing:
    def test_mixed_sizes_one_dispatch_byte_identical(self):
        node = FViewNode(64 * 2**20, n_regions=len(SIZES))
        qps, fts = [], []
        for i, n in enumerate(SIZES):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"t{i}", n, seed=100 + i)
            qps.append(qp)
            fts.append(ft)
        pends = [submit_request(qp, ft, PIPE) for qp, ft in zip(qps, fts)]
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1      # ONE stacked executable
        for pend, (ref, _), ft, qp in zip(pends, solo_refs(SIZES, PIPE),
                                          fts, qps):
            res = pend.wait()
            assert res.count == ref.count
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(ref.rows))
            assert res.shipped_bytes == ref.shipped_bytes
            # padded rows are NOT billed: each request pays its own bytes
            assert res.read_bytes == ft.n_bytes
            assert qp.bytes_read_pool == ft.n_bytes

    def test_mixed_sizes_groupby_merge_parity(self):
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=128),)
        node = FViewNode(64 * 2**20, n_regions=len(SIZES))
        pends, fts = [], []
        for i, n in enumerate(SIZES):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"g{i}", n, seed=100 + i, card=13)
            fts.append(ft)
            pends.append(submit_request(qp, ft, pipe))
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1
        refs = solo_refs(SIZES, pipe, card=13)
        for pend, ft, (ref, data) in zip(pends, fts, refs):
            merged = merge_group_partials(ft, pipe, [pend.wait()]).groups
            for k in np.unique(data["c0"]):
                m = data["c0"] == k
                cnt, s, _, _ = merged[int(k)]
                assert cnt == int(m.sum())
                np.testing.assert_allclose(
                    np.asarray(s), [data["c1"][m].sum(),
                                    data["c2"][m].sum()],
                    rtol=1e-3, atol=1e-3)

    def test_different_buckets_do_not_coalesce(self):
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        ft1, _ = word_table(qp1, "small", 200, seed=1)    # bucket 256
        ft2, _ = word_table(qp2, "big", 700, seed=2)      # bucket 1024
        submit_request(qp1, ft1, PIPE)
        submit_request(qp2, ft2, PIPE)
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 2

    def test_zero_retrace_across_sizes_in_bucket(self):
        """K different-sized tables in one bucket cost ONE trace, and a
        later round with *other* sizes in the same bucket costs zero."""
        clear_cache()
        node = FViewNode(64 * 2**20, n_regions=3)
        qps = [open_connection(node) for _ in range(3)]

        def round_of(sizes, tag):
            fts = [word_table(qp, f"{tag}{i}", n, seed=i)[0]
                   for i, (qp, n) in enumerate(zip(qps, sizes))]
            for qp, ft in zip(qps, fts):
                submit_request(qp, ft, PIPE)
            node.settle()

        round_of((300, 512, 400), "a")
        cp = compile_pipeline(FTable("x", tuple(Column(f"c{i}")
                                                for i in range(8))), PIPE)
        warm = cp.traces
        round_of((260, 510, 384), "b")       # same bucket, new sizes
        round_of((511, 257, 303), "c")
        assert cp.traces == warm             # stacked executable fully cached


class TestStackedStrings:
    STRS = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
            b"errr", b"the error is late here"]

    def _req(self, n, width, seed):
        rng = np.random.default_rng(seed)
        strs = [self.STRS[j] for j in rng.integers(0, len(self.STRS), n)]
        return string_table(f"s{seed}", strs, width), strs

    def test_batched_regex_matches_solo(self):
        import re as pyre
        pipe = (op.RegexMatch("error"),)
        node = FViewNode(64 * 2**20, n_regions=3)
        reqs = []
        # different row counts (one 128 bucket) AND widths (one 32 bucket)
        for i, (n, w) in enumerate([(100, 24), (128, 32), (77, 17)]):
            qp = open_connection(node)
            (ft, mat, lens), strs = self._req(n, w, seed=i)
            pend = submit_request(qp, ft, pipe, strings=mat, lengths=lens)
            reqs.append((pend, qp, strs, mat, w))
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1      # ONE vmapped DFA dispatch
        for pend, qp, strs, mat, w in reqs:
            res = pend.wait()
            expect = [bool(pyre.search(b"error", s[:w])) for s in strs]
            assert np.asarray(res.mask).tolist() == expect
            assert res.shipped_bytes == len(strs)     # 1 byte/row, no pad
            assert res.read_bytes == mat.shape[0] * mat.shape[1]

    def test_crypt_strings_pin_width_and_stay_correct(self):
        """Pre-crypt string requests batch only at identical widths (the
        CTR keystream is positional over the byte flattening); stacked
        results still decrypt/match exactly."""
        import re as pyre
        from repro.kernels import ref as kref
        import jax.numpy as jnp
        key, nonce = (5, 7), 9
        pipe = (op.Crypt(key=key, nonce=nonce, when="pre"),
                op.RegexMatch("error"))
        node = FViewNode(64 * 2**20, n_regions=3)
        reqs = []
        for i, n in enumerate([60, 64, 41]):       # same width, mixed rows
            qp = open_connection(node)
            (ft, mat, lens), strs = self._req(n, 32, seed=10 + i)
            enc = np.asarray(kref.ctr_crypt(
                jnp.asarray(mat.reshape(-1).astype(np.uint32)),
                jnp.asarray(key, jnp.uint32), nonce)
            ).astype(np.uint8).reshape(mat.shape)
            pend = submit_request(qp, ft, pipe, strings=enc, lengths=lens)
            reqs.append((pend, strs))
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1
        for pend, strs in reqs:
            got = np.asarray(pend.wait().mask).tolist()
            assert got == [bool(pyre.search(b"error", s[:32])) for s in strs]

    def test_crypt_width_mismatch_dispatches_separately(self):
        pipe = (op.Crypt(key=(1, 2), nonce=3, when="pre"),
                op.RegexMatch("fine"),)
        node = FViewNode(64 * 2**20, n_regions=2)
        for i, w in enumerate((24, 32)):           # same 32-bucket widths
            qp = open_connection(node)
            (ft, mat, lens), _ = self._req(50, w, seed=20 + i)
            submit_request(qp, ft, pipe, strings=mat, lengths=lens)
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 2       # width pinned by crypt


class TestStackedJoin:
    def test_batched_join_matches_solo(self):
        pipe = (op.JoinSmall(probe_key="c0", build_table="cust",
                             build_key="k", build_cols=("v",)),)
        sizes = (300, 512, 400)

        def setup(node):
            rng = np.random.default_rng(7)
            qp0 = open_connection(node)
            build = FTable("cust", (Column("k", "i32"), Column("v")),
                           n_rows=40)
            alloc_table_mem(qp0, build)
            bk = rng.permutation(64)[:40].astype(np.int32)
            bv = rng.random(40).astype(np.float32)
            table_write(qp0, build, build.encode({"k": bk, "v": bv}))
            return qp0

        node = FViewNode(64 * 2**20, n_regions=4)
        setup(node)
        pends = []
        for i, n in enumerate(sizes):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"p{i}", n, seed=200 + i, card=64)
            pends.append(submit_request(qp, ft, pipe))
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1       # ONE broadcast-build stack

        ref_node = FViewNode(64 * 2**20, n_regions=4)
        setup(ref_node)
        for pend, (i, n) in zip(pends, enumerate(sizes)):
            qp = open_connection(ref_node)
            ft, _ = word_table(qp, f"p{i}", n, seed=200 + i, card=64)
            ref = farview_request(qp, ft, pipe).finalize()
            res = pend.wait()
            assert res.count == ref.count
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(ref.rows))


class TestMixedKindRound:
    def test_one_dispatch_per_group(self):
        """A round mixing word selects (3 sizes), regex strings (2) and
        join probes (2) costs exactly three stacked dispatches."""
        node = FViewNode(128 * 2**20, n_regions=8)
        qp0 = open_connection(node)
        build = FTable("b", (Column("k", "i32"), Column("v")), n_rows=16)
        alloc_table_mem(qp0, build)
        rng = np.random.default_rng(0)
        table_write(qp0, build, build.encode(
            {"k": rng.permutation(32)[:16].astype(np.int32),
             "v": rng.random(16).astype(np.float32)}))
        jpipe = (op.JoinSmall(probe_key="c0", build_table="b",
                              build_key="k", build_cols=("v",)),)
        for i, n in enumerate((300, 512, 400)):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"w{i}", n, seed=i)
            submit_request(qp, ft, PIPE)
        for i, n in enumerate((50, 64)):
            qp = open_connection(node)
            ft, mat, lens = string_table(
                f"s{i}", [b"x error y", b"ok"] * (n // 2), 16)
            submit_request(qp, ft, (op.RegexMatch("error"),),
                           strings=mat, lengths=lens)
        for i, n in enumerate((200, 256)):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"j{i}", n, seed=50 + i, card=32)
            submit_request(qp, ft, jpipe)
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 3


class TestCloseConnection:
    def test_close_cancels_queued_requests(self):
        node = FViewNode(64 * 2**20, n_regions=2)
        qp1, qp2 = open_connection(node), open_connection(node)
        ft1, _ = word_table(qp1, "a", 256, seed=1)
        ft2, d2 = word_table(qp2, "b", 256, seed=2)
        doomed = submit_request(qp1, ft1, PIPE)
        alive = submit_request(qp2, ft2, PIPE)
        close_connection(qp1)
        with pytest.raises(FarviewError, match="closed"):
            doomed.wait()
        # the survivor still dispatches and the freed region's new tenant
        # sees no ghost traffic
        qp3 = open_connection(node)
        assert qp3.region == qp1.region
        node.flush()
        assert alive.wait().count == int((d2["c1"] < 0.2).sum())
        assert qp3.requests == 0
        assert node.regions[qp3.region].reconfigurations == 0
        # new verbs on the closed QPair are refused outright, not queued
        with pytest.raises(FarviewError, match="closed"):
            submit_request(qp1, ft1, PIPE)

    def test_failed_dispatch_not_counted(self):
        """node.dispatches is a launch counter: an error round (unknown
        join build table) must not inflate it."""
        node = FViewNode(64 * 2**20, n_regions=1)
        qp = open_connection(node)
        ft, _ = word_table(qp, "p", 128, seed=3, card=8)
        bad = (op.JoinSmall(probe_key="c0", build_table="nope",
                            build_key="k", build_cols=("v",)),)
        pend = submit_request(qp, ft, bad)
        before = node.dispatches
        with pytest.raises(KeyError):
            node.flush()
        assert node.dispatches == before
        with pytest.raises(KeyError):
            pend.wait()


class TestCacheKeyNormalization:
    def test_interpret_none_and_resolved_share_entry(self):
        import jax
        clear_cache()
        ft = FTable("x", tuple(Column(f"c{i}") for i in range(8)))
        resolved = jax.default_backend() != "tpu"
        p_auto = compile_pipeline(ft, PIPE)                    # interpret=None
        p_expl = compile_pipeline(ft, PIPE, interpret=resolved)
        assert p_auto is p_expl
        assert cache_info() == 1


class TestShapeBucketPadding:
    """PR 10 satellite: quarter-octave pad targets close the pow2
    padding-waste gap. Coalescing still groups by `pow2_bucket`; the
    stacked dispatch pads to `shape_bucket` of its largest member."""

    def test_ladder_invariants(self):
        for n in range(1, 300_000, 173):
            sb, pb = op.shape_bucket(n), op.pow2_bucket(n)
            assert n <= sb <= pb
            if n > 8:
                step = 1 << ((n - 1).bit_length() - 3)
                assert sb % step == 0           # on the quarter-octave rung
                assert sb <= 1.25 * n           # the waste bound

    def test_hash_partition_waste_regression(self):
        """The regime the fix targets: hash partitions land at n/k + eps
        rows, just past a power of two, and pow2 rounding paid ~1.3x of
        the dispatch in padding. The finer ladder must stay under 1.25x
        — this assertion is the regression guard."""
        rng = np.random.default_rng(7)
        sizes = 1_000_000 // 3 + rng.integers(0, 400, 64)
        valid = int(sizes.sum())
        pow2 = sum(op.pow2_bucket(int(n)) for n in sizes)
        fine = sum(op.shape_bucket(int(n)) for n in sizes)
        assert pow2 > 1.3 * valid       # what the old target wasted
        assert fine <= 1.25 * valid     # the new bound, forever
        assert fine < pow2

    def test_fine_pad_round_one_dispatch_byte_identical(self):
        """Sizes sharing a pow2 bucket but below its top still coalesce
        into ONE launch at the finer rung, byte-identical to solo and
        with padded rows invisible to accounting."""
        sizes = (9000, 9500, 8300)      # pow2 bucket 16384, rung 10240
        assert op.shape_bucket(max(sizes)) < op.pow2_bucket(max(sizes))
        node = FViewNode(64 * 2**20, n_regions=len(sizes))
        qps, fts = [], []
        for i, n in enumerate(sizes):
            qp = open_connection(node)
            ft, _ = word_table(qp, f"fp{i}", n, seed=100 + i)
            qps.append(qp)
            fts.append(ft)
        pends = [submit_request(qp, ft, PIPE) for qp, ft in zip(qps, fts)]
        before = node.dispatches
        node.flush()
        assert node.dispatches == before + 1
        for pend, (ref, _), ft, qp in zip(pends, solo_refs(sizes, PIPE),
                                          fts, qps):
            res = pend.wait()
            assert res.count == ref.count
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(ref.rows))
            assert res.shipped_bytes == ref.shipped_bytes
            assert res.read_bytes == ft.n_bytes
            assert qp.bytes_read_pool == ft.n_bytes
