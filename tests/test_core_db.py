"""Farview core (paper workload) integration tests: client API, pool,
pipelines, offload engine, multi-client behaviour."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import operators as op
from repro.core.client import (FViewNode, FarviewError, alloc_table_mem,
                               close_connection, farview_request,
                               free_table_mem, merge_group_partials,
                               open_connection, table_read, table_write)
from repro.core.pipeline import clear_cache, cache_info
from repro.core.table import FTable, Column, string_table


def make_table(qp, n=2048, seed=0, card=0):
    rng = np.random.default_rng(seed)
    cols = tuple(Column(f"c{i}", "i32" if (i == 0 and card) else "f32")
                 for i in range(8))
    ft = FTable("t", cols, n_rows=n)
    alloc_table_mem(qp, ft)
    data = {}
    for i in range(8):
        if i == 0 and card:
            data["c0"] = rng.integers(0, card, n).astype(np.int32)
        else:
            data[f"c{i}"] = rng.normal(size=n).astype(np.float32)
    table_write(qp, ft, ft.encode(data))
    return ft, data


# ---------------------------------------------------------------------------
# client API surface (paper §4.2)
# ---------------------------------------------------------------------------
class TestClientAPI:
    def test_connection_region_binding(self):
        node = FViewNode(16 * 2**20, n_regions=3)
        qps = [open_connection(node) for _ in range(3)]
        assert len({q.region for q in qps}) == 3
        with pytest.raises(FarviewError):
            open_connection(node)           # all regions bound
        close_connection(qps[0])
        q4 = open_connection(node)          # region reclaimed
        assert q4.region == qps[0].region

    def test_table_read_roundtrip(self):
        node = FViewNode(16 * 2**20)
        qp = open_connection(node)
        ft, data = make_table(qp, n=500)
        rows = np.asarray(table_read(qp, ft))
        np.testing.assert_allclose(rows[:, 1], data["c1"], rtol=1e-6)
        assert qp.bytes_shipped == ft.n_bytes

    def test_alloc_free_reuse(self):
        node = FViewNode(16 * 2**20)
        qp = open_connection(node)
        free0 = node.pool.free_pages
        ft, _ = make_table(qp, n=4096)
        assert node.pool.free_pages < free0
        free_table_mem(qp, ft)
        assert node.pool.free_pages == free0

    def test_reconfiguration_counter(self):
        """Swapping pipelines reconfigures the region (paper's ms-scale
        partial reconfiguration -> jit-cache dispatch)."""
        node = FViewNode(16 * 2**20)
        qp = open_connection(node)
        ft, _ = make_table(qp)
        p1 = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        p2 = (op.Select((op.Predicate("c1", ">", 0.0),)),)
        farview_request(qp, ft, p1)
        farview_request(qp, ft, p1)   # same signature: no reconfig
        farview_request(qp, ft, p2)
        region = node.regions[qp.region]
        assert region.reconfigurations == 2


# ---------------------------------------------------------------------------
# pipeline semantics (paper §5)
# ---------------------------------------------------------------------------
class TestPipelines:
    def setup_method(self):
        self.node = FViewNode(32 * 2**20)
        self.qp = open_connection(self.node)

    def test_projection_only(self):
        ft, data = make_table(self.qp)
        res = farview_request(self.qp, ft, (op.Project(("c2", "c5")),))
        assert int(res.count) == ft.n_rows
        got = np.asarray(res.rows[: int(res.count)])
        np.testing.assert_allclose(got[:, 2], data["c2"], rtol=1e-6)
        np.testing.assert_allclose(got[:, 5], data["c5"], rtol=1e-6)
        assert np.all(got[:, 0] == 0)       # dropped columns zeroed
        # shipped = only 2 columns worth
        assert res.shipped_bytes == ft.n_rows * 2 * 4

    def test_smart_addressing_byte_accounting(self):
        """Smart addressing reads only projected columns from the pool
        (Fig. 7: column-granular DRAM reads)."""
        ft, data = make_table(self.qp)
        res_std = farview_request(self.qp, ft, (op.Project(("c3",)),))
        res_sa = farview_request(self.qp, ft, (op.SmartAddress(("c3",)),))
        assert res_sa.read_bytes == ft.n_rows * 4          # 1 column
        assert res_std.read_bytes == ft.n_rows * 8 * 4     # whole rows
        got = np.asarray(res_sa.rows[: int(res_sa.count)])
        np.testing.assert_allclose(got[:, 0], data["c3"], rtol=1e-6)

    def test_multi_predicate_and(self):
        ft, data = make_table(self.qp)
        pipe = (op.Select((op.Predicate("c1", "<", 0.5),
                           op.Predicate("c2", ">", -0.5),
                           op.Predicate("c3", "<=", 1.0))),)
        res = farview_request(self.qp, ft, pipe)
        mask = ((data["c1"] < 0.5) & (data["c2"] > -0.5)
                & (data["c3"] <= 1.0))
        assert int(res.count) == int(mask.sum())

    def test_selectivity_drives_shipped_bytes(self):
        """Fig. 8 economics: shipped bytes proportional to selectivity."""
        ft, data = make_table(self.qp, n=4096)
        shipped = {}
        for q, sel in [(100, 1e9), (50, 0.0), (25, -0.6745)]:
            pipe = (op.Select((op.Predicate("c1", "<", sel),)),)
            res = farview_request(self.qp, ft, pipe)
            shipped[q] = res.shipped_bytes
        assert shipped[100] > shipped[50] > shipped[25]
        assert abs(shipped[50] / shipped[100] - 0.5) < 0.05
        assert abs(shipped[25] / shipped[100] - 0.25) < 0.05

    def test_distinct(self):
        ft, data = make_table(self.qp, card=23)
        res = farview_request(self.qp, ft, (op.Distinct(("c0",),
                                                        n_buckets=256),))
        merged = merge_group_partials(ft, (), [res]).groups
        assert set(merged) == set(np.unique(data["c0"]).tolist())

    def test_group_by_aggregates(self):
        ft, data = make_table(self.qp, card=17)
        pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=256),)
        res = farview_request(self.qp, ft, pipe)
        merged = merge_group_partials(ft, pipe, [res]).groups
        for k in np.unique(data["c0"]):
            mask = data["c0"] == k
            cnt, s, mn, mx = merged[int(k)]
            assert cnt == int(mask.sum())
            np.testing.assert_allclose(
                np.asarray(s), [data["c1"][mask].sum(),
                                data["c2"][mask].sum()], rtol=1e-3, atol=1e-3)
            np.testing.assert_allclose(
                np.asarray(mn), [data["c1"][mask].min(),
                                 data["c2"][mask].min()], rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(mx), [data["c1"][mask].max(),
                                 data["c2"][mask].max()], rtol=1e-5)

    def test_crypt_pre_and_post(self):
        from repro.kernels import ops as kops
        ft, data = make_table(self.qp)
        words = ft.encode(data)
        # encrypt at rest
        u32 = jnp.asarray(words.reshape(-1), jnp.float32).view(jnp.uint32)
        enc = kops.crypt(u32, np.array([3, 5], np.uint32), 11)
        table_write(self.qp, ft,
                    np.asarray(enc.view(jnp.float32)).reshape(words.shape))
        pipe = (op.Crypt(key=(3, 5), nonce=11, when="pre"),
                op.Select((op.Predicate("c1", "<", 0.0),)))
        res = farview_request(self.qp, ft, pipe)
        assert int(res.count) == int((data["c1"] < 0).sum())
        # post-encryption: response decrypts back to the projection
        table_write(self.qp, ft, words)
        pipe2 = (op.Project(("c0",)), op.Crypt(key=(9, 9), nonce=3,
                                               when="post"))
        res2 = farview_request(self.qp, ft, pipe2)
        resp = jnp.asarray(np.asarray(res2.rows).reshape(-1)).view(jnp.uint32)
        dec = kops.crypt(resp, np.array([9, 9], np.uint32), 3)
        got = np.asarray(dec.view(jnp.float32)).reshape(res2.rows.shape)
        np.testing.assert_allclose(got[: ft.n_rows, 0], data["c0"],
                                   rtol=1e-6)

    def test_regex_request(self):
        import re as pyre
        strs = [b"error: disk full", b"all fine", b"ERROR", b"warn: error",
                b"errr"] * 40
        ft, mat, lens = string_table("logs", strs, 32)
        res = farview_request(self.qp, ft, (op.RegexMatch("error"),),
                              strings=mat, lengths=lens)
        expect = [bool(pyre.search(b"error", s)) for s in strs]
        assert np.asarray(res.mask).tolist() == expect

    def test_pipeline_order_validation(self):
        ft, _ = make_table(self.qp)
        bad = (op.GroupBy("c0", ("c1",)),
               op.Select((op.Predicate("c1", "<", 0.0),)))
        with pytest.raises(ValueError):
            farview_request(self.qp, ft, bad)
        with pytest.raises(ValueError):
            op.validate_pipeline((op.Project(("c0",)),
                                  op.SmartAddress(("c1",))))

    def test_pipeline_cache(self):
        clear_cache()
        ft, _ = make_table(self.qp)
        pipe = (op.Select((op.Predicate("c1", "<", 0.25),)),)
        farview_request(self.qp, ft, pipe)
        n1 = cache_info()
        farview_request(self.qp, ft, pipe)
        assert cache_info() == n1           # cached, not recompiled


# ---------------------------------------------------------------------------
# sharded offload engine (multi-node generalization)
# ---------------------------------------------------------------------------
class TestOffload:
    def test_offload_matches_single_node(self):
        import jax
        from repro.core.offload import run_offloaded, shard_table
        from repro.launch.mesh import make_test_mesh
        if jax.device_count() < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh((1, 1), ("data", "model"))
        rng = np.random.default_rng(3)
        n = 1024
        ft = FTable("t", tuple(Column(f"c{i}") for i in range(8)), n_rows=n)
        rows = rng.normal(size=(n, 8)).astype(np.float32)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        sharded = shard_table(mesh, "model", jnp.asarray(rows))
        res = run_offloaded(mesh, "model", ft, pipe, sharded, n)
        assert res.result.count == int((rows[:, 1] < 0).sum())
        assert res.shipped_fraction < 1.0

    def test_multi_client_fair_accounting(self):
        node = FViewNode(32 * 2**20, n_regions=6)
        qps = [open_connection(node) for _ in range(6)]
        fts = []
        for i, qp in enumerate(qps):
            ft, _ = make_table(qp, n=512, seed=i)
            fts.append(ft)
        pipe = (op.Select((op.Predicate("c1", "<", 0.0),)),)
        for qp, ft in zip(qps, fts):
            farview_request(qp, ft, pipe)
        assert all(qp.requests == 1 for qp in qps)
        assert node.pool.stats.requests == 6
