"""End-to-end training driver: ~100M-param xLSTM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This is the (b) end-to-end deliverable: the full production path — config,
data pipeline, AdamW, fault-tolerant loop with async checkpoints — on the
xlstm-125m architecture at a width that fits CPU. Default runs a 4-layer
~14M-param slice for wall-clock sanity; --full-depth uses all 12 layers
(~125M params, slower). Loss on the Markov stream decreases; checkpoints
land in --ckpt-dir and a restart resumes.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.models.lm import LM
from repro.runtime.train_loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--full-depth", action="store_true")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    if not args.full_depth:
        # keep the family (3 mLSTM + 1 sLSTM per group), narrow for CPU
        cfg = cfg.replace(n_layers=4, d_model=256, n_heads=4,
                          vocab=8192, param_dtype="float32")
    else:
        cfg = cfg.replace(param_dtype="float32")
    lm = LM(cfg)
    import jax
    n_params = sum(int(x.size) for x in jax.tree.leaves(
        jax.eval_shape(lm.init, jax.random.PRNGKey(0))))
    print(f"arch=xlstm-125m layers={cfg.n_layers} d={cfg.d_model} "
          f"params={n_params/1e6:.1f}M")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=100)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    loop = TrainLoop(lm, tcfg, pipe)
    stats = loop.run(args.steps)
    l = stats.losses
    print(f"steps={stats.steps_done} restarts={stats.restarts} "
          f"nan_events={stats.nan_events} "
          f"ewma_step={stats.step_time_ewma*1e3:.0f}ms")
    k = max(1, len(l) // 10)
    print(f"loss first{k}={np.mean(l[:k]):.4f} -> last{k}="
          f"{np.mean(l[-k:]):.4f}")
    assert np.mean(l[-k:]) < np.mean(l[:k]), "loss must decrease"
    print("checkpoints at:", loop.ckpt.all_steps())


if __name__ == "__main__":
    main()
