"""Serving with the disaggregated KV pool: FV vs RCPU vs LCPU, batched.

    PYTHONPATH=src python examples/serve_far_kv.py

Brings up a granite-family model on an 8-device (forced CPU) mesh with the
KV cache sequence-sharded over the "model" axis — the Farview pool — and
decodes the same batch under all three read paths, verifying the logits
agree and printing each mode's modeled per-step network bytes.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import smoke_config
from repro.core.far_kv import shipped_bytes_per_layer
from repro.launch.mesh import make_test_mesh, set_mesh
from repro.models.lm import LM

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = smoke_config(get_config("granite-3-2b"))
key = jax.random.PRNGKey(0)
lm_pool = LM(cfg, mesh=mesh, dp_axes=("data",))
lm_local = LM(cfg)
params = lm_pool.init(key)

B, MAX_S, GEN = 4, 256, 16
prompt = jax.random.randint(key, (B, 8), 0, cfg.vocab)

print(f"mesh {dict(mesh.shape)}; cache (B={B}, S={MAX_S}) seq-sharded "
      f"over 'model' = the disaggregated pool axis")
outs = {}
with set_mesh(mesh):
    for mode, lm in [("far", lm_pool), ("naive", lm_pool),
                     ("local", lm_local)]:
        cache = lm.init_cache(B, MAX_S, jnp.float32)
        pos = 0
        # teacher-forced prefill through the decode path
        for t in range(prompt.shape[1]):
            logits, cache = lm.decode_step(
                params, cache, {"tokens": prompt[:, t:t + 1]},
                jnp.int32(pos), jnp.int32(pos), mode=mode)
            pos += 1
        toks = [jnp.argmax(logits[:, -1], -1)]
        for _ in range(GEN - 1):
            logits, cache = lm.decode_step(
                params, cache, {"tokens": toks[-1][:, None]},
                jnp.int32(pos), jnp.int32(pos), mode=mode)
            pos += 1
            toks.append(jnp.argmax(logits[:, -1], -1))
        outs[mode] = np.stack([np.asarray(t) for t in toks], 1)
        ship = shipped_bytes_per_layer(
            mode, batch=B, hq=cfg.n_heads, hkv=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, seq_len=MAX_S, tp=4)
        print(f"  mode={mode:6s} generated {outs[mode].shape} tokens; "
              f"modeled bytes/layer/step = {ship:,}")

assert np.array_equal(outs["far"], outs["naive"]), "FV != RCPU tokens"
assert np.array_equal(outs["far"], outs["local"]), "FV != LCPU tokens"
print("all three read paths generated identical tokens ✓")
red = (shipped_bytes_per_layer("naive", batch=B, hq=cfg.n_heads,
                               hkv=cfg.n_kv_heads,
                               head_dim=cfg.resolved_head_dim,
                               seq_len=MAX_S, tp=4)
       / shipped_bytes_per_layer("far", batch=B, hq=cfg.n_heads,
                                 hkv=cfg.n_kv_heads,
                                 head_dim=cfg.resolved_head_dim,
                                 seq_len=MAX_S, tp=4))
print(f"push-down reduces per-step network bytes {red:.1f}x at S={MAX_S} "
      f"(grows linearly with S)")
