"""The paper's evaluation workload end-to-end (Figures 7-12 queries).

    PYTHONPATH=src python examples/farview_queries.py

Runs every operator class the paper evaluates — projection + smart
addressing, selection at three selectivities, distinct, group-by with
aggregation, regex matching, encryption — on one Farview node with six
concurrent clients, printing the data-movement economics per query.

FARVIEW_EXAMPLE_ROWS scales every table down proportionally (the tier-1
example smoke test runs this script at a few hundred rows so the
documented entry points cannot silently rot).
"""
import os

import numpy as np
import jax.numpy as jnp

from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               merge_group_partials, open_connection,
                               table_write)
from repro.core.table import FTable, Column, string_table
from repro.data.pipeline import db_table_columns
from repro.kernels import ops as kops

node = FViewNode(256 * 2**20, n_regions=6)
rng = np.random.default_rng(7)
n = int(os.environ.get("FARVIEW_EXAMPLE_ROWS", 16384))
# the other clients' tables scale with n (floors keep the queries
# meaningful at smoke-test sizes)
n_wide = max(64, (n * 2048) // 16384)
n_str = max(64, (n * 4096) // 16384)
n_enc = max(64, (n * 4096) // 16384)
n_join = max(128, (n * 8192) // 16384)


def report(tag, res):
    frac = res.shipped_bytes / max(res.read_bytes, 1)
    print(f"  {tag:<38s} read {res.read_bytes:>10,} B -> "
          f"shipped {res.shipped_bytes:>10,} B  ({100*frac:5.1f}%)")


# -- client 1: selection at three selectivities (Fig. 8) --------------------
qp1 = open_connection(node)
ft = FTable("t", tuple(Column(f"c{i}") for i in range(8)), n_rows=n)
alloc_table_mem(qp1, ft)
data = db_table_columns(n, seed=1)
table_write(qp1, ft, ft.encode(data))
print("SELECT * FROM t WHERE ...  (selectivity sweep)")
for pct, preds in [
    (100, ()),
    (50, (op.Predicate("c1", "<", 0.0),)),
    (25, (op.Predicate("c1", "<", 0.0), op.Predicate("c2", "<", 0.0))),
]:
    pipe = (op.Select(preds),) if preds else (op.Project(
        tuple(f"c{i}" for i in range(8))),)
    report(f"selectivity ~{pct}%", farview_request(qp1, ft, pipe))

# -- client 2: projection vs smart addressing (Fig. 7) ----------------------
qp2 = open_connection(node)
wide = FTable("wide", tuple(Column(f"c{i}") for i in range(128)),
              n_rows=n_wide)
alloc_table_mem(qp2, wide)
wdata = {f"c{i}": rng.normal(size=n_wide).astype(np.float32)
         for i in range(128)}
table_write(qp2, wide, wide.encode(wdata))
print("SELECT c0,c1,c2 FROM wide  (512 B tuples)")
report("standard projection", farview_request(
    qp2, wide, (op.Project(("c0", "c1", "c2")),)))
report("smart addressing", farview_request(
    qp2, wide, (op.SmartAddress(("c0", "c1", "c2")),)))

# -- client 3: distinct + group-by (Fig. 9) ---------------------------------
qp3 = open_connection(node)
gt = FTable("g", (Column("k", "i32"), Column("v")), n_rows=n)
alloc_table_mem(qp3, gt)
keys = rng.integers(0, 40, n).astype(np.int32)
vals = rng.normal(size=n).astype(np.float32)
table_write(qp3, gt, gt.encode({"k": keys, "v": vals}))
print("SELECT DISTINCT k FROM g / SELECT k, SUM(v) ... GROUP BY k")
rd = farview_request(qp3, gt, (op.Distinct(("k",), n_buckets=256),))
report("distinct (40 uniques)", rd)
rg = farview_request(qp3, gt, (op.GroupBy("k", ("v",), n_buckets=256),))
report("group-by + sum", rg)
groups = merge_group_partials(gt, (), [rg]).groups
assert len(groups) == len(np.unique(keys))
chk = sorted(groups)[0]
np.testing.assert_allclose(
    float(np.asarray(groups[chk][1]).ravel()[0]),
    vals[keys == chk].sum(), rtol=1e-3)
print(f"  verified against numpy: {len(groups)} groups exact")

# -- client 4: regex matching (Fig. 10) -------------------------------------
qp4 = open_connection(node)
strs = []
for i in range(n_str):
    s = bytes(rng.integers(97, 123, size=28).astype(np.uint8))
    strs.append((b"order-error" + s) if i % 2 else s)
sft, mat, lens = string_table("logs", strs, 40)
print("SELECT * FROM logs WHERE line ~ 'error'")
rr = farview_request(qp4, sft, (op.RegexMatch("error"),),
                     strings=mat, lengths=lens)
print(f"  matched {int(np.asarray(rr.mask).sum())}/{len(strs)} rows, "
      f"decision mask = {rr.shipped_bytes:,} B shipped")

# -- client 5: encrypted table, decrypt-on-read (Fig. 11) -------------------
qp5 = open_connection(node)
eft = FTable("enc", tuple(Column(f"c{i}") for i in range(8)), n_rows=n_enc)
alloc_table_mem(qp5, eft)
edata = db_table_columns(n_enc, seed=9)
ewords = eft.encode(edata)
u32 = jnp.asarray(ewords.reshape(-1), jnp.float32).view(jnp.uint32)
enc = kops.crypt(u32, np.array([21, 42], np.uint32), 99)
table_write(qp5, eft, np.asarray(enc.view(jnp.float32)).reshape(
    ewords.shape))
print("SELECT c0 FROM enc  (data at rest encrypted; cipher on the stream)")
re_ = farview_request(qp5, eft, (op.Crypt(key=(21, 42), nonce=99,
                                          when="pre"),
                                 op.Project(("c0",))))
got = np.asarray(re_.rows[: int(re_.count), 0])
np.testing.assert_allclose(got, edata["c0"], rtol=1e-6)
report("decrypt+project verified", re_)

# -- client 6: small-table join (paper §Conclusions future work) ------------
qp6 = open_connection(node)
orders = FTable("orders6", (Column("cust", "i32"), Column("amount")),
                n_rows=n_join)
alloc_table_mem(qp6, orders)
od = {"cust": rng.integers(0, 200, n_join).astype(np.int32),
      "amount": rng.random(n_join).astype(np.float32)}
table_write(qp6, orders, orders.encode(od))
cust = FTable("customers6", (Column("cust", "i32"), Column("discount")),
              n_rows=50)
alloc_table_mem(qp6, cust)
ck = rng.permutation(200)[:50].astype(np.int32)
table_write(qp6, cust, cust.encode(
    {"cust": ck, "discount": rng.random(50).astype(np.float32)}))
print("SELECT o.*, c.discount FROM orders o JOIN customers c ON o.cust=c.cust"
      " WHERE o.amount < 0.3")
rj = farview_request(qp6, orders, (
    op.Select((op.Predicate("amount", "<", 0.3),)),
    op.JoinSmall(probe_key="cust", build_table="customers6",
                 build_key="cust", build_cols=("discount",))))
expect = int(((od["amount"] < 0.3) & np.isin(od["cust"], ck)).sum())
assert int(rj.count) == expect
report(f"join: {int(rj.count)} matched rows", rj)

# -- node accounting ---------------------------------------------------------
st = node.pool.stats
print(f"\nnode totals: {st.requests} farview requests, "
      f"{st.bytes_shipped:,} B shipped over the 'network'")
