"""Quickstart: the Farview public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. stand up a smart disaggregated memory node,
2. allocate + write a table into its paged pool,
3. push a selection+projection pipeline down to the memory,
4. compare bytes shipped vs a plain RDMA read,
5. run a group-by with client-side overflow merge.

FARVIEW_EXAMPLE_ROWS scales the table down (the tier-1 example smoke test
runs this script at a few hundred rows so the documented entry points
cannot silently rot).
"""
import os

import numpy as np

from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               merge_group_partials, open_connection,
                               table_read, table_write)
from repro.core.table import FTable, Column

# 1. a Farview node: 64 MiB pool, 6 dynamic regions (paper's eval config)
node = FViewNode(capacity_bytes=64 * 2**20, n_regions=6)
qp = open_connection(node)

# 2. an 8-column table (paper's base tables: 8 attributes)
rng = np.random.default_rng(0)
n = int(os.environ.get("FARVIEW_EXAMPLE_ROWS", 8192))
ft = FTable("orders", tuple(Column(f"c{i}") for i in range(8)), n_rows=n)
alloc_table_mem(qp, ft)
data = {f"c{i}": rng.normal(size=n).astype(np.float32) for i in range(8)}
data["c0"] = rng.integers(0, 20, n).astype(np.float32)   # group key
table_write(qp, ft, ft.encode(data))

# 3. SELECT c1, c2 FROM orders WHERE c1 < 0.0 AND c2 > -1.0 — pushed down
pipe = (op.Project(("c1", "c2")),
        op.Select((op.Predicate("c1", "<", 0.0),
                   op.Predicate("c2", ">", -1.0))))
res = farview_request(qp, ft, pipe)
print(f"selection: {int(res.count)}/{n} rows survive")

# 4. the Farview economics: bytes over the wire vs a plain read
plain = table_read(qp, ft)
print(f"plain read ships   {ft.n_bytes:>9,} B")
print(f"push-down ships    {res.shipped_bytes:>9,} B "
      f"({100 * res.shipped_bytes / ft.n_bytes:.1f}%)")

# 5. SELECT c0, COUNT(*), SUM(c3) FROM orders GROUP BY c0
gpipe = (op.GroupBy("c0", ("c3",), n_buckets=256),)
gres = farview_request(qp, ft, gpipe)
groups = merge_group_partials(ft, gpipe, [gres]).groups
k0 = sorted(groups)[0]
cnt, s, mn, mx = groups[k0]
print(f"group-by: {len(groups)} groups; group {k0}: count={cnt} "
      f"sum={float(np.asarray(s).ravel()[0]):.2f}")
print(f"group-by shipped {gres.shipped_bytes:,} B "
      f"(vs {ft.n_bytes:,} B raw)")
