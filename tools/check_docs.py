"""Docs drift guard (CI lint job + tier-1 via tests/test_docs.py).

Two checks keep the documentation wired to reality:

  1. every intra-repo markdown link (``[text](relative/path.md)``) in the
     repo's ``*.md`` files resolves to an existing file — a renamed module
     or a deleted doc breaks the build, not the reader;
  2. the tier-1 verify command quoted in ROADMAP.md and README.md is the
     same pytest invocation the CI workflow actually runs — the one
     command a contributor is told to trust must be the one CI trusts.

External URLs, anchors, and GitHub site-relative links (targets that
resolve outside the repo, like the CI badge's ``../../actions/...``) are
out of scope. Exit code 0 = clean, 1 = drift (each finding on stderr).

    python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", "__pycache__", ".claude", ".venv", "node_modules"}

# the canonical tier-1 invocation; ROADMAP/README may prefix PYTHONPATH=…
TIER1_CMD = "python -m pytest -x -q"
TIER1_FILES = ("ROADMAP.md", "README.md")
CI_WORKFLOW = os.path.join(".github", "workflows", "ci.yml")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def markdown_files(root: str = ROOT) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def broken_links(md_path: str, root: str = ROOT) -> list[tuple[str, str]]:
    """(target, reason) for every intra-repo link that does not resolve."""
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.realpath(
            os.path.join(os.path.dirname(md_path), path))
        if not resolved.startswith(os.path.realpath(root) + os.sep):
            continue        # GitHub site-relative (e.g. the CI badge)
        if not os.path.exists(resolved):
            bad.append((target, f"{os.path.relpath(md_path, root)} links "
                                f"to missing {target!r}"))
    return bad


def tier1_drift(root: str = ROOT) -> list[str]:
    """Places where the quoted tier-1 command and CI disagree."""
    problems = []
    for name in TIER1_FILES:
        path = os.path.join(root, name)
        if not os.path.exists(path):
            problems.append(f"{name} is missing (tier-1 command lives there)")
            continue
        with open(path, encoding="utf-8") as f:
            if TIER1_CMD not in f.read():
                problems.append(
                    f"{name} does not quote the tier-1 command "
                    f"{TIER1_CMD!r}")
    ci = os.path.join(root, CI_WORKFLOW)
    if not os.path.exists(ci):
        problems.append(f"{CI_WORKFLOW} is missing")
    else:
        with open(ci, encoding="utf-8") as f:
            if TIER1_CMD not in f.read():
                problems.append(
                    f"{CI_WORKFLOW} does not run the tier-1 command "
                    f"{TIER1_CMD!r} that ROADMAP/README promise")
    return problems


def main() -> int:
    findings: list[str] = []
    for md in markdown_files():
        findings.extend(reason for _, reason in broken_links(md))
    findings.extend(tier1_drift())
    for f in findings:
        print(f"docs-drift: {f}", file=sys.stderr)
    n = len(markdown_files())
    print(f"# checked {n} markdown files; {len(findings)} problems")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
