"""`tools.analyze`: thin launcher for farlint (`repro.analyze`).

Exists so `python -m tools.analyze` works from a bare checkout — CI's
lint job runs it with no package installed and no jax. The real
implementation lives in src/repro/analyze/ (stdlib-only)."""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
