import sys

import tools.analyze  # noqa: F401  (bootstraps src/ onto sys.path)
from repro.analyze.cli import main

if __name__ == "__main__":
    sys.exit(main())
